"""Integration tests: data pipeline, optimizer, trainer loop,
checkpoint/restart, coded layer, serving engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import DataConfig, SyntheticTokens, make_pipeline
from repro.models import build_model
from repro.optim import AdamWConfig, CompressionConfig, apply_updates, init_state
from repro.parallel.coded_layer import CodedLinear
from repro.serve import Request, ServeEngine
from repro.train import TrainConfig, Trainer, checkpoint


class TestData:
    def test_deterministic_and_seekable(self):
        cfg = DataConfig(vocab=128, seq_len=32, global_batch=4)
        src = SyntheticTokens(cfg)
        b0a, b0b = src.batch_at(0), src.batch_at(0)
        np.testing.assert_array_equal(b0a["tokens"], b0b["tokens"])
        assert not np.array_equal(src.batch_at(1)["tokens"], b0a["tokens"])
        # labels are next-token shifted
        np.testing.assert_array_equal(b0a["tokens"][:, 1:], b0a["labels"][:, :-1])

    def test_host_sharding_partitions(self):
        cfg = DataConfig(vocab=128, seq_len=16, global_batch=4)
        full = SyntheticTokens(cfg).batch_at(3)["tokens"]
        parts = [SyntheticTokens(
            DataConfig(vocab=128, seq_len=16, global_batch=4,
                       host_count=2, host_index=h)).batch_at(3)["tokens"]
            for h in range(2)]
        np.testing.assert_array_equal(np.concatenate(parts), full)

    def test_prefetch(self):
        it = make_pipeline(DataConfig(vocab=64, seq_len=8, global_batch=2))
        b = next(it)
        assert b["tokens"].shape == (2, 8)
        it.close()


class TestOptimizer:
    def test_adamw_descends_quadratic(self):
        cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                          weight_decay=0.0)
        params = {"w": jnp.ones((4,)) * 5.0}
        state = init_state(cfg, params)
        for _ in range(60):
            grads = {"w": 2 * params["w"]}          # d/dw ||w||^2
            params, state, m = apply_updates(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 1.0

    def test_bf16_moments(self):
        cfg = AdamWConfig(moment_dtype="bfloat16")
        state = init_state(cfg, {"w": jnp.ones((3,))})
        assert state["m"]["w"].dtype == jnp.bfloat16

    def test_clip(self):
        cfg = AdamWConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0)
        params = {"w": jnp.zeros((2,))}
        state = init_state(cfg, params)
        _, _, m = apply_updates(cfg, params, {"w": jnp.ones((2,)) * 1e6}, state)
        assert float(m["grad_norm"]) > 1e5  # norm reported pre-clip


class TestTrainerLoop:
    def _setup(self, tmp_path, steps=6, schedule_total=None):
        cfg = get_smoke_config("phi3-mini-3.8b")
        model = build_model(cfg, dtype=jnp.float32)
        dcfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)
        tcfg = TrainConfig(steps=steps, ckpt_every=3, log_every=100,
                           ckpt_dir=str(tmp_path / "ckpt"))
        # the LR-schedule horizon must be the FULL run length even when a
        # phase stops early (otherwise resume sees a different schedule)
        tr = Trainer(model, AdamWConfig(lr=1e-3, warmup_steps=2,
                                        total_steps=schedule_total or steps),
                     tcfg)
        factory = lambda start: make_pipeline(dcfg, start)  # noqa: E731
        return tr, factory

    def test_loss_decreases(self, tmp_path):
        tr, factory = self._setup(tmp_path, steps=20)
        _, _, hist = tr.fit(factory, resume=False)
        first = np.mean([h["loss"] for h in hist[:4]])
        last = np.mean([h["loss"] for h in hist[-4:]])
        assert last < first, (first, last)

    def test_checkpoint_restart_exact(self, tmp_path):
        tr, factory = self._setup(tmp_path, steps=6)
        p1, o1, hist1 = tr.fit(factory)
        # "crash" after completion; a fresh trainer resumes from step 6
        tr2, factory2 = self._setup(tmp_path, steps=6)
        p2, o2, hist2 = tr2.fit(factory2)
        assert hist2 == []  # nothing left to do
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))

    def test_mid_run_resume_matches_uninterrupted(self, tmp_path):
        # uninterrupted 6-step run
        tr_a, factory_a = self._setup(tmp_path / "a", steps=6)
        pa, _, _ = tr_a.fit(factory_a)
        # interrupted: 3 steps (ckpt at 3), then resume to 6
        tr_b1, factory_b = self._setup(tmp_path / "b", steps=3,
                                       schedule_total=6)
        tr_b1.fit(factory_b)
        tr_b2, factory_b2 = self._setup(tmp_path / "b", steps=6)
        pb, _, hist = tr_b2.fit(factory_b2)
        assert hist[0]["step"] == 3
        for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)

    def test_compression_still_learns(self, tmp_path):
        cfg = get_smoke_config("phi3-mini-3.8b")
        model = build_model(cfg, dtype=jnp.float32)
        dcfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)
        tcfg = TrainConfig(steps=16, ckpt_dir=None,
                           compression=CompressionConfig(mode="int8"))
        tr = Trainer(model, AdamWConfig(lr=1e-3, warmup_steps=2,
                                        total_steps=16), tcfg)
        _, _, hist = tr.fit(lambda s: make_pipeline(dcfg, s), resume=False)
        assert np.mean([h["loss"] for h in hist[-3:]]) < \
            np.mean([h["loss"] for h in hist[:3]])


class TestCheckpoint:
    def test_atomic_roundtrip(self, tmp_path):
        state = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                 "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
        checkpoint.save(tmp_path, 7, state)
        assert checkpoint.latest_step(tmp_path) == 7
        out = checkpoint.restore(tmp_path, 7, state)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(state["a"]))
        assert out["b"]["c"].dtype == jnp.bfloat16

    def test_keep_last(self, tmp_path):
        state = {"x": jnp.zeros((1,))}
        for s in range(5):
            checkpoint.save(tmp_path, s, state, keep_last=2)
        steps = sorted(int(p.name[5:13]) for p in tmp_path.glob("ckpt_*.npz"))
        assert steps == [3, 4]

    def test_shape_mismatch_raises(self, tmp_path):
        checkpoint.save(tmp_path, 0, {"x": jnp.zeros((2,))})
        with pytest.raises(ValueError):
            checkpoint.restore(tmp_path, 0, {"x": jnp.zeros((3,))})


class TestCodedLinear:
    def test_matches_uncoded_any_pattern(self):
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.standard_normal((24, 36)), jnp.float32)
        layer = CodedLinear.build(w, n_workers=6, stragglers=2, seed=1)
        x = jnp.asarray(rng.standard_normal((5, 24)), jnp.float32)
        ref = np.asarray(x @ w)
        import itertools
        for pat in itertools.combinations(range(6), 2):
            done = np.ones(6, bool)
            done[list(pat)] = False
            out = layer.apply(x, jnp.asarray(done))
            np.testing.assert_allclose(np.asarray(out), ref,
                                       rtol=2e-4, atol=2e-4)

    def test_storage_overhead_is_omega_over_k(self):
        w = jnp.ones((16, 32))
        layer = CodedLinear.build(w, n_workers=6, stragglers=2)
        # n shards of width d_out/k: total = (n/k) * logical size
        assert layer.coded.shape == (6, 16, 8)

    def test_differentiable(self):
        rng = np.random.default_rng(1)
        w = jnp.asarray(rng.standard_normal((8, 12)), jnp.float32)
        layer = CodedLinear.build(w, n_workers=4, stragglers=1, seed=0)
        x = jnp.asarray(rng.standard_normal((8,)), jnp.float32)

        def f(x):
            return layer.apply(x).sum()

        g = jax.grad(f)(x)
        ref = jax.grad(lambda x: (x @ w).sum())(x)
        np.testing.assert_allclose(np.asarray(g), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3)


class TestServeEngine:
    def test_batched_generation(self):
        cfg = get_smoke_config("phi3-mini-3.8b")
        model = build_model(cfg, dtype=jnp.float32)
        params = model.init(jax.random.key(0))
        eng = ServeEngine(model, params, cfg, batch_size=2, max_len=64)
        reqs = [Request(prompt=[1, 5, 9], max_new=4),
                Request(prompt=[1, 7], max_new=4),
                Request(prompt=[1, 2, 3, 4], max_new=4)]
        out = eng.run(reqs)
        assert all(len(r.output) == 4 for r in out)

    def test_coded_head_resilient(self):
        cfg = get_smoke_config("qwen3-14b")
        model = build_model(cfg, dtype=jnp.float32)
        params = model.init(jax.random.key(0))
        from repro.configs.base import CodedConfig
        eng = ServeEngine(model, params, cfg, batch_size=2, max_len=32,
                          coded=CodedConfig(enabled=True, n_workers=6,
                                            stragglers=2))
        rng = np.random.default_rng(0)
        hidden = jnp.asarray(rng.standard_normal((2, cfg.d_model)),
                             jnp.float32)
        head = params["embed"].T if cfg.tie_embeddings else params["head"]
        ref = np.asarray(hidden @ head)
        for _ in range(5):  # random straggler masks each step
            out = eng.coded_logits(hidden)
            np.testing.assert_allclose(np.asarray(out), ref,
                                       rtol=5e-3, atol=5e-3)
