"""CodedFleet session suite (repro.api.fleet / repro.cluster.fleet).

Covers: interleaved in-flight rounds across >= 2 attached plans on all
three transports with bitwise parity vs sequential execution, matvec ->
matmat microbatching (coalesced rounds decode each call's columns back
bitwise-identically to solo rounds), ``CodedFuture`` semantics
(``result`` / ``done`` / ``add_done_callback`` / cancellation of queued
calls), bounded-queue backpressure, per-call deadlines failing only the
affected future, ``fleet.close()`` fd/thread leak hygiene (alongside
the existing ServeEngine one), the ``REPRO_FLEET_MAX_INFLIGHT`` env
default, the standalone remote worker entry point
(``python -m repro.cluster.worker --connect``), and the consumer
surfaces sharing one fleet: serve-engine LM head via
``CodedConfig.fleet``, ``CodedMoE`` expert pipelining, and
``CodedAggregator.to_cluster(fleet=...)``.
"""

import concurrent.futures
import itertools
import os
import socket
import subprocess
import sys
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import CodedFleet, compile_plan
from repro.api.fleet import FleetDegraded, default_max_inflight
from repro.cluster import ScriptedFaults, StragglerFaults

TOL = dict(rtol=5e-3, atol=5e-3)


def block_sparse(rng, t, r, zeros, bs=8, dtype=np.float32):
    mask = rng.random((t // bs, r // bs)) >= zeros
    a = rng.standard_normal((t, r)).astype(dtype)
    return a * np.kron(mask, np.ones((bs, bs), dtype))


def all_straggler_masks(n, s):
    for pat in itertools.combinations(range(n), s):
        done = np.ones(n, bool)
        done[list(pat)] = False
        yield done


@pytest.fixture(scope="module")
def operands():
    rng = np.random.default_rng(3)
    t, r = 256, 144
    A = jnp.asarray(block_sparse(rng, t, r, 0.98))
    A2 = jnp.asarray(block_sparse(rng, t, 96, 0.98))
    xs = jnp.asarray(rng.standard_normal((8, t)), jnp.float32)
    return A, A2, xs


# ---------------------------------------------------------------------------
# In-flight rounds across plans, all transports
# ---------------------------------------------------------------------------


class TestInterleavedRounds:
    @pytest.mark.parametrize("transport", ["memory", "pipe", "tcp"])
    def test_two_plans_interleaved_bitwise(self, operands, transport):
        if transport != "memory":
            pytest.importorskip("scipy")
        A, A2, xs = operands
        n, s = 6, 2
        p1 = compile_plan(A, scheme="proposed", n=n, s=s, backend="packed")
        p2 = compile_plan(A2, scheme="cyclic31", n=n, s=s, backend="packed")
        masks = list(all_straggler_masks(n, s))[:6]
        with CodedFleet(n, transport=transport, max_inflight=4) as fleet:
            h1 = fleet.attach(p1)
            h2 = fleet.attach(p2)
            # submit everything up front: rounds from both plans are in
            # flight simultaneously, demuxed by (plan, round) on one
            # uniform event stream
            futs = []
            for i, done in enumerate(masks):
                futs.append(("p1", i, done, h1.submit_matvec(xs[i], done)))
                futs.append(("p2", i, done, h2.submit_matvec(xs[i], done)))
            for which, i, done, fut in futs:
                plan = p1 if which == "p1" else p2
                want = np.asarray(plan.matvec(xs[i], jnp.asarray(done)))
                np.testing.assert_array_equal(np.asarray(fut.result()), want)
            assert len(h1.reports) == len(masks)
            assert len(h2.reports) == len(masks)

    def test_matmat_and_aggregate_futures(self):
        rng = np.random.default_rng(5)
        t = 144
        A = jnp.asarray(block_sparse(rng, t, 72, 0.95))
        B = jnp.asarray(block_sparse(rng, t, 48, 0.95))
        mm = compile_plan(A, scheme="proposed", n=12, k_A=3, k_B=3,
                          backend="packed")
        agg = compile_plan(scheme="proposed", n=6, s=2)
        payloads = [{"g": jnp.asarray(rng.standard_normal(16), jnp.float32)}
                    for _ in range(6)]
        with CodedFleet(12, max_inflight=4) as fleet:
            hm = fleet.attach(mm)
            ha = fleet.attach(agg)
            done_mm = np.ones(12, bool)
            done_ag = np.ones(6, bool)
            fm = hm.submit_matmat(B, done_mm)
            fa = ha.submit_aggregate(payloads, done_ag)
            np.testing.assert_array_equal(
                np.asarray(fm.result()),
                np.asarray(mm.matmat(B, jnp.asarray(done_mm))))
            np.testing.assert_allclose(
                np.asarray(fa.result()["g"]),
                np.asarray(agg.aggregate(payloads,
                                         jnp.asarray(done_ag))["g"]),
                rtol=1e-5, atol=1e-5)

    def test_race_mode_pattern_parity(self, operands):
        # race-mode decode must be bitwise the in-process plan under
        # the *observed* pattern the report records
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        with CodedFleet(6, max_inflight=2) as fleet:
            h = fleet.attach(plan)
            futs = [h.submit_matvec(xs[i]) for i in range(4)]
            outs = [np.asarray(f.result()) for f in futs]
        # rounds launch in submission order (round ids are monotonic),
        # so sorting reports by round maps each call to its pattern
        # even when completions interleave or calls coalesce
        reports = sorted(h.reports, key=lambda r: r.round)
        assert sum(r.calls for r in reports) == 4
        call_patterns = [r.pattern for r in reports for _ in range(r.calls)]
        for i, (out, pat) in enumerate(zip(outs, call_patterns)):
            want = np.asarray(plan.matvec(xs[i], jnp.asarray(pat)))
            np.testing.assert_array_equal(out, want)


# ---------------------------------------------------------------------------
# Microbatching
# ---------------------------------------------------------------------------


class TestMicrobatching:
    def test_queued_matvecs_coalesce_bitwise(self, operands):
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        # slow the workers so rounds 2..4 are provably queued while
        # round 1 is in flight -> they must coalesce into ONE round
        faults = StragglerFaults(time_scale=1.0, seed=1)
        with CodedFleet(6, max_inflight=1, microbatch=True,
                        faults=faults) as fleet:
            h = fleet.attach(plan)
            futs = [h.submit_matvec(xs[i]) for i in range(4)]
            outs = [np.asarray(f.result()) for f in futs]
        reports = list(h.reports)
        # the queued calls coalesced: strictly fewer rounds than calls
        assert len(reports) <= 2
        assert max(r.calls for r in reports) >= 3
        assert sum(r.calls for r in reports) == 4
        # every call decodes bitwise vs the in-process plan under its
        # round's observed pattern -- coalescing is invisible to values
        call_patterns = [r.pattern for r in reports for _ in range(r.calls)]
        for i, (out, pat) in enumerate(zip(outs, call_patterns)):
            want = np.asarray(plan.matvec(xs[i], jnp.asarray(pat)))
            np.testing.assert_array_equal(out, want)

    def test_microbatch_off_keeps_rounds_solo(self, operands):
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        faults = StragglerFaults(time_scale=1.0, seed=1)
        with CodedFleet(6, max_inflight=1, microbatch=False,
                        faults=faults) as fleet:
            h = fleet.attach(plan)
            futs = [h.submit_matvec(xs[i]) for i in range(3)]
            [f.result() for f in futs]
        assert [r.calls for r in h.reports] == [1, 1, 1]

    def test_column_cap_bounds_coalescing(self, operands):
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        faults = StragglerFaults(time_scale=1.0, seed=1)
        with CodedFleet(6, max_inflight=1, microbatch=True,
                        microbatch_cols=2, faults=faults) as fleet:
            h = fleet.attach(plan)
            futs = [h.submit_matvec(xs[i]) for i in range(5)]
            [f.result() for f in futs]
        # width cap 2: after the first solo round, coalesced rounds
        # stop growing once 2 columns are packed
        assert all(r.calls <= 2 for r in h.reports)
        assert sum(r.calls for r in h.reports) == 5


# ---------------------------------------------------------------------------
# Futures: callbacks, cancellation, deadlines, backpressure
# ---------------------------------------------------------------------------


class TestCodedFuture:
    def test_done_and_callback(self, operands):
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        fired = threading.Event()
        with CodedFleet(6) as fleet:
            h = fleet.attach(plan)
            fut = h.submit_matvec(xs[0])
            fut.add_done_callback(lambda f: fired.set())
            fut.result()
            assert fired.wait(timeout=5)
            assert fut.done() and not fut.cancelled()
            assert fut.exception() is None
            # callbacks added after resolution fire immediately
            late = threading.Event()
            fut.add_done_callback(lambda f: late.set())
            assert late.is_set()

    def test_cancel_queued_call(self, operands):
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        faults = StragglerFaults(time_scale=1.0, seed=1)
        with CodedFleet(6, max_inflight=1, microbatch=False,
                        faults=faults) as fleet:
            h = fleet.attach(plan)
            f1 = h.submit_matvec(xs[0])     # launches immediately
            f2 = h.submit_matvec(xs[1])     # queued behind it
            assert f2.cancel()
            assert f2.cancelled()
            with pytest.raises(concurrent.futures.CancelledError):
                f2.result()
            # the launched round is not cancellable and still resolves
            assert not f1.cancel()
            np.testing.assert_allclose(
                np.asarray(f1.result()), np.asarray(xs[0] @ A), **TOL)
        assert len(h.reports) == 1          # the cancelled call never ran

    def test_deadline_fails_only_its_future(self, operands):
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        slow = StragglerFaults(time_scale=30.0, seed=1)   # ~minutes/task
        with CodedFleet(6, max_inflight=2, faults=slow) as fleet:
            h = fleet.attach(plan)
            doomed = h.submit_matvec(xs[0], np.ones(6, bool), deadline=0.2)
            with pytest.raises(TimeoutError):
                doomed.result()
            assert isinstance(doomed.exception(), TimeoutError)
        # the fleet survives the expiry: nothing else was torn down

    def test_backpressure_bounds_queue(self, operands):
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        faults = StragglerFaults(time_scale=1.0, seed=1)
        with CodedFleet(6, max_inflight=1, microbatch=False, queue_cap=2,
                        faults=faults) as fleet:
            h = fleet.attach(plan)
            t0 = time.perf_counter()
            futs = [h.submit_matvec(xs[i % 8]) for i in range(6)]
            blocked_s = time.perf_counter() - t0
            [f.result() for f in futs]
        # with only 2 unresolved calls admitted at a time, the 6
        # submissions cannot all have been accepted instantly
        assert blocked_s > 0.05


# ---------------------------------------------------------------------------
# Session hygiene
# ---------------------------------------------------------------------------


class TestSessionLifecycle:
    def test_close_joins_fleet_threads(self, operands):
        A, A2, xs = operands
        p1 = compile_plan(A, scheme="proposed", n=6, s=2, backend="packed")
        p2 = compile_plan(A2, scheme="proposed", n=6, s=2, backend="packed")
        with CodedFleet(6) as fleet:
            h1, h2 = fleet.attach(p1), fleet.attach(p2)
            h1.matvec(xs[0])
            h2.matvec(xs[1])
        time.sleep(0.05)
        leftover = [t.name for t in threading.enumerate()
                    if t.name.startswith(("coded-fleet", "cluster-worker",
                                          "cluster-beat"))]
        assert leftover == []

    def test_tcp_close_releases_sockets_and_threads(self, operands):
        import gc
        import warnings

        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        with warnings.catch_warnings():
            warnings.simplefilter("error", ResourceWarning)
            with CodedFleet(6, transport="tcp") as fleet:
                h = fleet.attach(plan)
                h.matvec(xs[0])
            gc.collect()                # unclosed sockets would warn here
        for t in threading.enumerate():
            assert not t.name.startswith(("coded-fleet", "cluster-tcp",
                                          "cluster-beat", "cluster-worker"))

    def test_detach_keeps_fleet_serving_other_plans(self, operands):
        A, A2, xs = operands
        p1 = compile_plan(A, scheme="proposed", n=6, s=2, backend="packed")
        p2 = compile_plan(A2, scheme="proposed", n=6, s=2, backend="packed")
        with CodedFleet(6) as fleet:
            h1, h2 = fleet.attach(p1), fleet.attach(p2)
            h1.matvec(xs[0])
            h1.detach()
            with pytest.raises(RuntimeError, match="detached"):
                h1.submit_matvec(xs[0])
            np.testing.assert_allclose(np.asarray(h2.matvec(xs[1])),
                                       np.asarray(xs[1] @ A2), **TOL)

    def test_submit_after_close_raises(self, operands):
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        fleet = CodedFleet(6)
        h = fleet.attach(plan)
        fleet.close()
        with pytest.raises(RuntimeError, match="closed"):
            h.submit_matvec(xs[0])

    def test_env_var_sets_inflight_default(self, monkeypatch):
        monkeypatch.delenv("REPRO_FLEET_MAX_INFLIGHT", raising=False)
        assert default_max_inflight() == 8
        monkeypatch.setenv("REPRO_FLEET_MAX_INFLIGHT", "3")
        assert default_max_inflight() == 3
        fleet = CodedFleet(2)
        try:
            assert fleet.max_inflight == 3
        finally:
            fleet.close()

    def test_all_workers_dead_between_rounds_fails_fast(self, operands):
        from repro.cluster import FailStop

        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        # every worker dies on its first served task: the round in
        # flight (or the ones after it) must surface the wipeout as a
        # RuntimeError on the future, and later submissions must
        # fail fast instead of hanging forever
        with CodedFleet(6, faults=FailStop(
                {w: 0 for w in range(6)})) as fleet:
            h = fleet.attach(plan)
            with pytest.raises(RuntimeError, match="dead"):
                h.matvec(xs[0], deadline=30.0)
            with pytest.raises(RuntimeError, match="dead"):
                h.submit_matvec(xs[1])

    def test_failstop_requeues_across_plans(self, operands):
        from repro.cluster import FailStop

        A, A2, xs = operands
        p1 = compile_plan(A, scheme="proposed", n=6, s=2, backend="packed")
        p2 = compile_plan(A2, scheme="proposed", n=6, s=2, backend="packed")
        with CodedFleet(6, faults=FailStop({0: 0})) as fleet:
            h1, h2 = fleet.attach(p1), fleet.attach(p2)
            # worker 0 dies serving its first task; BOTH plans' shards
            # held by it must re-home and both plans keep answering
            np.testing.assert_allclose(np.asarray(h1.matvec(xs[0])),
                                       np.asarray(xs[0] @ A), **TOL)
            np.testing.assert_allclose(np.asarray(h2.matvec(xs[1])),
                                       np.asarray(xs[1] @ A2), **TOL)
            np.testing.assert_allclose(np.asarray(h1.matvec(xs[2])),
                                       np.asarray(xs[2] @ A), **TOL)
            total_deaths = sum(r.deaths for r in
                               list(h1.reports) + list(h2.reports))
            assert total_deaths == 1


# ---------------------------------------------------------------------------
# Remote worker entry point (multi-host tcp)
# ---------------------------------------------------------------------------


class TestRemoteWorker:
    @pytest.mark.slow
    def test_remote_workers_join_tcp_fleet(self, operands):
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        # reserve a port for the coordinator so the "remote" workers
        # (separate python processes running the module entry point)
        # know where to dial before the fleet exists
        probe = socket.socket()
        probe.bind(("127.0.0.1", 0))
        port = probe.getsockname()[1]
        probe.close()
        env = {**os.environ,
               "PYTHONPATH": os.pathsep.join(
                   ["src"] + os.environ.get("PYTHONPATH", "").split(
                       os.pathsep)).rstrip(os.pathsep)}
        procs = [subprocess.Popen(
            [sys.executable, "-m", "repro.cluster.worker",
             "--connect", f"127.0.0.1:{port}", "--id", str(w)],
            env=env, cwd=os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))))
            for w in range(2)]
        try:
            with CodedFleet(2, transport="tcp",
                            transport_opts={"spawn": False,
                                            "port": port}) as fleet:
                h = fleet.attach(plan)
                done = np.ones(6, bool)
                done[[2, 5]] = False
                got = np.asarray(h.matvec(xs[0], done))
                want = np.asarray(plan.matvec(xs[0], jnp.asarray(done)))
                np.testing.assert_array_equal(got, want)
            for p in procs:
                assert p.wait(timeout=30) == 0
        finally:
            for p in procs:
                if p.poll() is None:
                    p.kill()

    def test_cli_rejects_bad_address(self):
        from repro.cluster.worker import main

        with pytest.raises(SystemExit):
            main(["--connect", "no-port-here", "--id", "0"])


# ---------------------------------------------------------------------------
# Consumer surfaces sharing one fleet
# ---------------------------------------------------------------------------


class TestSharedConsumers:
    def test_engine_and_aggregator_share_one_fleet(self):
        import jax

        from repro.configs import get_smoke_config
        from repro.configs.base import CodedConfig
        from repro.models import build_model
        from repro.parallel.coded_grads import CodedAggregator
        from repro.serve import ServeEngine

        cfg = get_smoke_config("qwen3-14b")
        model = build_model(cfg, dtype=jnp.float32)
        params = model.init(jax.random.key(0))
        rng = np.random.default_rng(0)
        with CodedFleet(6, max_inflight=4) as fleet:
            eng = ServeEngine(
                model, params, cfg, batch_size=2, max_len=32,
                coded=CodedConfig(enabled=True, n_workers=6, stragglers=2,
                                  fleet=fleet))
            agg = CodedAggregator.build(6, 2, seed=0)
            agg_handle = agg.to_cluster(fleet=fleet)
            assert agg_handle.fleet is fleet
            assert eng.coded_cluster.fleet is fleet

            hidden = jnp.asarray(rng.standard_normal(
                (2, cfg.d_model)), jnp.float32)
            head = params["embed"].T if cfg.tie_embeddings \
                else params["head"]
            out = eng.coded_logits(hidden)
            np.testing.assert_allclose(np.asarray(out),
                                       np.asarray(hidden @ head), **TOL)

            shard_grads = [
                {"g": jnp.asarray(rng.standard_normal(8), jnp.float32)}
                for _ in range(4)]
            payloads = [agg.worker_payload(w, shard_grads)
                        for w in range(6)]
            done = jnp.asarray(np.ones(6, bool))
            got = np.asarray(agg.aggregate(payloads, done,
                                           cluster=agg_handle)["g"])
            want = np.asarray(agg.aggregate(payloads, done)["g"])
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

            # engine close only DETACHES from the shared fleet; the
            # aggregator keeps serving on the same workers
            eng.close()
            assert eng.coded_cluster is None
            got2 = np.asarray(agg.aggregate(payloads, done,
                                            cluster=agg_handle)["g"])
            np.testing.assert_allclose(got2, want, rtol=1e-5, atol=1e-5)

    def test_coded_moe_pipelines_experts_on_fleet(self):
        import jax

        from repro.configs.base import MoEConfig
        from repro.models.moe import CodedMoE, init_moe_params

        moe = MoEConfig(n_experts=2, top_k=1, d_expert=48)
        p = init_moe_params(jax.random.key(0), 64, moe)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((2, 4, 64)), jnp.float32)
        done = np.ones(6, bool)
        done[[1, 4]] = False
        local = CodedMoE(p, moe, n_workers=6, stragglers=2,
                         backend="packed")
        with CodedFleet(6, max_inflight=4) as fleet:
            dispatched = CodedMoE(p, moe, n_workers=6, stragglers=2,
                                  backend="packed", fleet=fleet)
            o_fleet, aux_f = dispatched(x, jnp.asarray(done))
            o_local, aux_l = local(x, jnp.asarray(done))
            np.testing.assert_array_equal(np.asarray(o_fleet),
                                          np.asarray(o_local))
            np.testing.assert_allclose(float(aux_f), float(aux_l))
            # 3 plans per expert attached and served
            assert len(dispatched.gate[0].reports) == 1
            dispatched.detach()

    def test_trainer_reships_through_fleet_handle(self):
        from repro.train.trainer import TrainConfig, Trainer

        rng = np.random.default_rng(0)
        t, r = 128, 72
        dense = jnp.asarray(rng.standard_normal((t, r)), jnp.float32)
        sparse = jnp.asarray(block_sparse(rng, t, r, 0.995))
        plan = compile_plan(sparse, scheme="proposed", n=6, s=2)
        assert plan.backend == "packed"

        class TinyModel:
            def init(self, key):
                return {"w": dense}

            def train_loss(self, params, batch):
                return jnp.mean(params["w"] ** 2)

        with CodedFleet(6) as fleet:
            handle = fleet.attach(plan)
            shards_before = handle.bytes_shards
            trainer = Trainer(
                TinyModel(),
                __import__("repro.optim.adamw",
                           fromlist=["AdamWConfig"]).AdamWConfig(lr=1e-3),
                TrainConfig(steps=1, retune_every=1, log_every=100),
                coded_plans=[(plan, lambda prm: prm["w"], handle)])
            trainer.fit(lambda start: iter(
                [{"x": np.zeros((1,), np.float32)}] * 4), resume=False)
            assert trainer.retunes and trainer.retunes[0]["changed"]
            assert trainer.retunes[0]["backend"] == "reference"
            assert trainer.retunes[0]["reshipped_bytes"] > 0
            assert handle.bytes_shards > shards_before


# ---------------------------------------------------------------------------
# Elastic membership: live join / graceful leave
# ---------------------------------------------------------------------------


def wait_until(pred, timeout=10.0):
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < timeout:
        if pred():
            return True
        time.sleep(0.02)
    return pred()


class TestElasticMembership:
    def test_add_worker_joins_and_serves(self, operands):
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        with CodedFleet(6) as fleet:
            h = fleet.attach(plan)
            h.matvec(xs[0])
            joiner = fleet.add_worker()
            assert joiner in fleet.live_workers()
            assert "join" in [e["kind"] for e in fleet.event_log]
            # ownership rebalanced off the most-loaded hosts: the
            # newcomer actually serves the already-attached plan
            assert wait_until(lambda: any(
                o == joiner for ps in fleet._plans.values()
                for o in ps.owner.values()))
            # and parity survives the re-homed rows
            done = np.ones(6, bool)
            np.testing.assert_array_equal(
                np.asarray(h.matvec(xs[1], done)),
                np.asarray(plan.matvec(xs[1], jnp.asarray(done))))

    def test_remove_worker_drains_without_deaths(self, operands):
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        with CodedFleet(6) as fleet:
            h = fleet.attach(plan)
            h.matvec(xs[0])
            fleet.remove_worker(5, drain=True)
            assert 5 not in fleet.live_workers()
            kinds = [e["kind"] for e in fleet.event_log]
            assert "leave" in kinds
            # drain-before-remove: no death notice, no suspicion
            assert "death" not in kinds and "suspect" not in kinds
            # resilience shrank before availability: k preserved
            assert wait_until(lambda: h.plan.n == 5)
            assert (h.plan.k, h.plan.s) == (4, 1)
            np.testing.assert_allclose(np.asarray(h.matvec(xs[1])),
                                       np.asarray(xs[1] @ A), **TOL)
            assert all(r.deaths == 0 for r in h.reports)

    def test_removing_last_worker_refuses(self, operands):
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        with CodedFleet(1) as fleet:
            h = fleet.attach(plan)
            h.matvec(xs[0])
            with pytest.raises(FleetDegraded, match="add a worker"):
                fleet.remove_worker(0)
            # the refused leave left the fleet serving
            np.testing.assert_allclose(np.asarray(h.matvec(xs[1])),
                                       np.asarray(xs[1] @ A), **TOL)

    def test_join_restores_full_resilience_after_loss(self, operands):
        from repro.cluster import FailStop

        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        with CodedFleet(6, faults=FailStop({0: 0})) as fleet:
            h = fleet.attach(plan)
            pid0 = h.plan_id
            # worker 0 dies serving its first task: the round still
            # answers, then the plan re-encodes for the 5 survivors
            np.testing.assert_allclose(np.asarray(h.matvec(xs[0])),
                                       np.asarray(xs[0] @ A), **TOL)
            assert wait_until(lambda: h.plan.n == 5)
            assert (h.plan.k, h.plan.s) == (4, 1)
            pid_shrunk = h.plan_id
            assert pid_shrunk != pid0
            # a replacement device joins: full strength restored
            fleet.add_worker()
            assert wait_until(lambda: h.plan.n == 6)
            assert (h.plan.k, h.plan.s) == (4, 2)
            assert h.plan_id != pid_shrunk
            np.testing.assert_allclose(np.asarray(h.matvec(xs[1])),
                                       np.asarray(xs[1] @ A), **TOL)

    def test_worker_capacities_quantize_throughput_ewmas(self, operands):
        with CodedFleet(4) as fleet:
            # no measurements yet: everyone is baseline
            assert fleet.worker_capacities([0, 1, 2, 3]) == [1, 1, 1, 1]
            # seeded EWMAs quantize to 1..levels, proportional to the
            # fastest; unmeasured workers get the median live rate
            fleet._rate.update({0: 4.0, 1: 1.0, 2: 2.0})
            assert fleet.worker_capacities([0, 1, 2]) == [4, 1, 2]
            assert fleet.worker_capacities([0, 1, 2, 3]) == [4, 1, 2, 2]

    def test_reencode_gives_measured_slow_worker_fewer_tiles(
            self, operands):
        """Closing the observe->re-encode loop: with a tracer on the
        fleet, ``observed_rates()`` feeds the measured per-worker
        compute rates into the re-encode's capacity cut, so a worker
        that *measured* slow (not just configured slow) owns strictly
        fewer rows of the new hetero encoding."""
        from repro.cluster.faults import adversarial_faults
        from repro.obs import Tracer, attribute

        A, _, xs = operands
        slow = 0
        plan = compile_plan(A, scheme="proposed", n=12, s=4,
                            backend="packed")
        tr = Tracer(capacity=4096)
        faults = adversarial_faults([slow], slowdown=60.0,
                                    time_scale=2e-3)
        with CodedFleet(6, faults=faults, tracer=tr) as fleet:
            h = fleet.attach(plan)
            for x in list(xs) * 2:
                h.matvec(x)
                # pacing: healthy workers drain between rounds, so
                # only the injected straggler accumulates lag
                time.sleep(0.01)
            rates = fleet.observed_rates()
            assert rates and slow in rates
            assert rates[slow] == min(rates.values())
            # sanity: the rates come from the tracer's round records
            assert attribute(tr.events()).suspects()[0] == slow
            pid0 = h.plan_id
            fleet.remove_worker(5, drain=True)
            assert wait_until(lambda: h.plan_id != pid0)
            # the cut followed the measured speeds: hetero scheme,
            # and the slow worker owns strictly the fewest rows
            assert h.plan.scheme.name == "proposed-hetero"
            owned = {w: 0 for w in fleet.live_workers()}
            for o in h._ps.owner.values():
                owned[o] += 1
            assert all(owned[slow] < owned[w] for w in owned
                       if w != slow)
            np.testing.assert_allclose(np.asarray(h.matvec(xs[1])),
                                       np.asarray(xs[1] @ A), **TOL)

    def test_metrics_track_roster_across_add_remove(self, operands):
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        with CodedFleet(6) as fleet:
            h = fleet.attach(plan)
            h.matvec(xs[0])
            joiner = fleet.add_worker()
            m = fleet.metrics()
            assert m["n_live"] == 7 and joiner in m["live_workers"]
            assert len(m["worker_capacities"]) == 7
            fleet.remove_worker(joiner, drain=True)
            fleet.remove_worker(0, drain=True)
            m = fleet.metrics()
            assert m["n_live"] == 5
            assert joiner not in m["live_workers"]
            assert 0 not in m["live_workers"]
            assert len(m["worker_capacities"]) == 5
            np.testing.assert_allclose(np.asarray(h.matvec(xs[1])),
                                       np.asarray(xs[1] @ A), **TOL)


# ---------------------------------------------------------------------------
# Graceful degradation: floors, shedding, re-encode edges
# ---------------------------------------------------------------------------


class TestGracefulDegradation:
    def test_reencode_is_journaled_under_fresh_plan_id(self, operands):
        from repro.cluster import FailStop

        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        with CodedFleet(6, faults=FailStop({2: 0})) as fleet:
            h = fleet.attach(plan)
            pid0 = h.plan_id
            np.testing.assert_allclose(np.asarray(h.matvec(xs[0])),
                                       np.asarray(xs[0] @ A), **TOL)
            assert wait_until(lambda: h.plan_id != pid0)
            kinds = [e["kind"] for e in fleet.event_log]
            assert "reencode" in kinds
            # the version that served round 1 stays replayable
            assert h.plan_version(pid0).n == 6

    def test_min_workers_floor_fails_fast(self, operands):
        from repro.cluster import FailStop

        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        with CodedFleet(6, faults=FailStop({w: 0 for w in range(5)}),
                        min_workers=3) as fleet:
            h = fleet.attach(plan)
            with pytest.raises(FleetDegraded, match="min_workers"):
                h.matvec(xs[0], deadline=30.0)
            # below the floor every later submission fails fast too,
            # and the error names the recovery action
            with pytest.raises(FleetDegraded, match="add_worker"):
                h.submit_matvec(xs[1])
            assert "degraded-floor" in [e["kind"] for e in fleet.event_log]

    def test_shed_admission_rejects_when_saturated(self, operands):
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        slow = StragglerFaults(time_scale=30.0, seed=1)
        with CodedFleet(6, faults=slow, admission="shed", queue_cap=2,
                        max_inflight=1, microbatch=False) as fleet:
            h = fleet.attach(plan)
            f1 = h.submit_matvec(xs[0], np.ones(6, bool), deadline=0.5)
            f2 = h.submit_matvec(xs[1], np.ones(6, bool), deadline=0.5)
            with pytest.raises(FleetDegraded, match="queue_cap") as ei:
                h.submit_matvec(xs[2], np.ones(6, bool))
            assert ei.value.action == "shed"
            for f in (f1, f2):          # shed calls never wedge others
                with pytest.raises(TimeoutError):
                    f.result(timeout=30.0)

    def test_queued_explicit_mask_fails_structured_across_reencode(
            self, operands):
        from repro.cluster import FailStop

        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        with CodedFleet(6, faults=FailStop({0: 0}), max_inflight=1,
                        microbatch=False) as fleet:
            h = fleet.attach(plan)
            # round 1 kills worker 0 -> the plan re-encodes once its
            # rounds drain; the queued explicit-mask call was built in
            # the old version's task coordinates and cannot be rebuilt
            f1 = h.submit_matvec(xs[0])
            f2 = h.submit_matvec(xs[1], np.ones(6, bool))
            np.testing.assert_allclose(np.asarray(f1.result()),
                                       np.asarray(xs[0] @ A), **TOL)
            with pytest.raises(FleetDegraded, match="re-encode") as ei:
                f2.result(timeout=30.0)
            assert ei.value.action == "re-encode"
            # race-mode calls survive the same transition fine
            np.testing.assert_allclose(np.asarray(h.matvec(xs[2])),
                                       np.asarray(xs[2] @ A), **TOL)


# ---------------------------------------------------------------------------
# Two-phase suspicion edge cases, all transports
# ---------------------------------------------------------------------------


class TestSuspicionEdgeCases:
    @pytest.mark.parametrize("transport", ["memory", "pipe", "tcp"])
    def test_partitioned_worker_suspected_not_failed(self, operands,
                                                     transport):
        if transport != "memory":
            pytest.importorskip("scipy")
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        warm = 2.5 if transport == "memory" else 15.0
        epoch = time.time() + warm
        faults = ScriptedFaults(
            windows=[{"kind": "partition", "worker": 0,
                      "t0": 0.0, "t1": 2.0}],
            epoch=epoch)
        with CodedFleet(6, transport=transport, faults=faults,
                        heartbeat_s=0.1, suspect_after=0.4,
                        suspect_grace=10.0, microbatch=False) as fleet:
            h = fleet.attach(plan)
            h.matvec(xs[0])                     # warm before the window
            while time.time() < epoch + 0.6:
                time.sleep(0.02)
            # phase 1: silent but IDLE -- no outstanding rows, so the
            # two-phase rule must neither suspect nor re-home it
            assert 0 in fleet.live_workers()
            assert 0 not in fleet._suspected
            # phase 2: give it outstanding rows mid-partition; a
            # wait-all round cannot finish until the partition heals,
            # and the LONG grace means the late beat un-suspects the
            # worker instead of a spurious fail-stop + requeue
            done = np.ones(6, bool)
            out = np.asarray(h.matvec(xs[1], done, deadline=60.0))
            assert time.time() >= epoch + 1.8   # resolved post-heal
            rep = h.reports[-1]
            assert rep.suspected == 0
            assert rep.deaths == 0
            assert rep.requeues == 0
            np.testing.assert_array_equal(
                out, np.asarray(plan.matvec(xs[1], jnp.asarray(done))))
            assert 0 in fleet.live_workers()
            assert wait_until(lambda: 0 not in fleet._suspected, 5.0)
            assert "death" not in [e["kind"] for e in fleet.event_log]


# ---------------------------------------------------------------------------
# Close robustness: idempotence, mid-round teardown, leak checks
# ---------------------------------------------------------------------------


class TestCloseRobustness:
    @pytest.mark.parametrize("transport", ["memory", "tcp"])
    def test_double_close_is_idempotent(self, operands, transport):
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        fleet = CodedFleet(6, transport=transport)
        h = fleet.attach(plan)
        h.matvec(xs[0])
        fleet.close()
        fleet.close()                           # second close is a no-op
        time.sleep(0.05)
        for t in threading.enumerate():
            assert not t.name.startswith(("coded-fleet", "cluster-tcp",
                                          "cluster-beat",
                                          "cluster-worker"))

    def test_close_mid_round_resolves_futures(self, operands):
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        slow = StragglerFaults(time_scale=30.0, seed=1)
        fleet = CodedFleet(6, faults=slow, microbatch=False)
        h = fleet.attach(plan)
        fut = h.submit_matvec(xs[0], np.ones(6, bool))
        time.sleep(0.2)
        fleet.close()                           # round still in flight
        with pytest.raises(RuntimeError, match="closed"):
            fut.result(timeout=10.0)            # resolved, never hangs
        assert fut.done()
        fleet.close()                           # idempotent afterwards
        time.sleep(0.05)
        leftover = [t.name for t in threading.enumerate()
                    if t.name.startswith(("coded-fleet", "cluster-worker",
                                          "cluster-beat"))]
        assert leftover == []

    def test_tcp_close_releases_fds(self, operands):
        import gc

        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")

        def run_once():
            with CodedFleet(4, transport="tcp") as fleet:
                h = fleet.attach(plan)
                h.matvec(xs[0])

        run_once()                              # warm lazy imports/caches
        gc.collect()
        time.sleep(0.2)
        before = len(os.listdir("/proc/self/fd"))
        run_once()
        gc.collect()
        time.sleep(0.2)
        after = len(os.listdir("/proc/self/fd"))
        assert after <= before + 2              # sockets + pipes released


# ---------------------------------------------------------------------------
# Observability + dynamic coalescing (metrics, group submits, idle pump)
# ---------------------------------------------------------------------------


class TestObservability:
    def test_fleet_and_handle_metrics_snapshot(self, operands):
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        with CodedFleet(6, max_inflight=4) as fleet:
            h = fleet.attach(plan)
            [h.submit_matvec(xs[i]).result() for i in range(3)]
            m = fleet.metrics()
            assert m["n_live"] == 6 and m["live_workers"] == list(range(6))
            assert m["inflight_rounds"] == 0 and m["queued_calls"] == 0
            assert len(m["worker_capacities"]) == 6
            pm = m["plans"][h.plan_id]
            assert pm["counters"]["submitted"] == 3
            assert pm["counters"]["resolved"] == 3
            assert pm["lat_ewma_ms"] > 0
            hm = h.metrics()                    # the per-handle slice
            assert hm["plan_id"] == h.plan_id
            assert hm["counters"] == pm["counters"]
            assert hm["fleet"]["n_live"] == 6

    def test_metrics_count_shed_and_deadline(self, operands):
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        slow = StragglerFaults(time_scale=20.0, seed=1)
        with CodedFleet(6, max_inflight=1, queue_cap=1, admission="shed",
                        microbatch=False, faults=slow) as fleet:
            h = fleet.attach(plan)
            futs = [h.submit_matvec(xs[0], deadline=0.05)]
            shed = 0
            for _ in range(8):                  # saturate the bounded queue
                try:
                    futs.append(h.submit_matvec(xs[0], deadline=0.05))
                except FleetDegraded as e:
                    assert e.action == "shed"
                    shed += 1
            for f in futs:
                with pytest.raises(TimeoutError):
                    f.result(timeout=20.0)
            hm = h.metrics()
            assert shed > 0 and hm["counters"]["shed"] == shed
            assert hm["counters"]["deadline_hit"] == len(futs)

    def test_metrics_after_close_direct_read(self, operands):
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        fleet = CodedFleet(6)
        h = fleet.attach(plan)
        h.matvec(xs[0])
        fleet.close()
        assert fleet.metrics()["plans"][h.plan_id][
            "counters"]["resolved"] == 1


class TestDynamicCoalescing:
    def test_set_microbatch_cols_retargets_live(self, operands):
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        faults = StragglerFaults(time_scale=1.0, seed=1)
        with CodedFleet(6, max_inflight=1, microbatch=True,
                        faults=faults) as fleet:
            h = fleet.attach(plan)
            h.set_microbatch_cols(2)            # per-plan cap, set live
            futs = [h.submit_matvec(xs[i]) for i in range(5)]
            [f.result() for f in futs]
            assert all(r.calls <= 2 for r in h.reports)
            assert h.metrics()["microbatch_cols"] == 2
            h.set_microbatch_cols(None)         # back to the fleet cap
            assert h.metrics()["microbatch_cols"] is None

    def test_submit_matvec_many_packs_one_round(self, operands):
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        # microbatch_cols=2 must NOT split an explicit group: the group
        # is cap-exempt, one round, per-call bitwise decode slices
        with CodedFleet(6, max_inflight=4, microbatch=True,
                        microbatch_cols=2) as fleet:
            h = fleet.attach(plan)
            futs = h.submit_matvec_many([xs[i] for i in range(5)])
            outs = [np.asarray(f.result()) for f in futs]
            reports = {id(f.report) for f in futs}
            assert len(reports) == 1            # exactly one round
            assert futs[0].report.calls == 5
            pat = futs[0].report.pattern
            for i, out in enumerate(outs):
                want = np.asarray(plan.matvec(xs[i], jnp.asarray(pat)))
                np.testing.assert_array_equal(out, want)

    def test_submit_group_wider_than_queue_cap_rejected(self, operands):
        # a group wider than the whole admission queue can never be
        # admitted, even against an idle fleet: blocking would self-
        # deadlock (only its own unsubmitted calls could free slots),
        # shedding would make every retry futile -- reject loudly
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        with CodedFleet(6, queue_cap=4) as fleet:
            h = fleet.attach(plan)
            with pytest.raises(ValueError, match="queue_cap"):
                h.submit_matvec_many([xs[i % 3] for i in range(5)])

    def test_group_nonblocking_shed_is_all_or_nothing(self, operands):
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")

        class FixedDelay:
            """Bounded 2s sleep per task: holds admission slots through
            the shed assertions without leaving workers asleep past
            fleet close (unlike an unbounded exponential tail)."""

            def delay(self, worker, task_row, work):
                return 2.0

            def should_fail(self, worker, tasks_done):
                return False

        with CodedFleet(6, faults=FixedDelay(), queue_cap=3,
                        max_inflight=1, microbatch=False) as fleet:
            h = fleet.attach(plan)
            f1 = h.submit_matvec(xs[0], np.ones(6, bool), deadline=0.5)
            with pytest.raises(FleetDegraded) as ei:  # 3 wanted, 2 free
                h.submit_matvec_many([xs[0], xs[1], xs[2]], block=False)
            assert ei.value.action == "shed"
            # all-or-nothing: the slots the shed group briefly held are
            # back, so a group that fits admits without blocking
            f2 = h.submit_matvec_many([xs[0], xs[1]], deadline=0.5,
                                      block=False)
            assert len(f2) == 2
            for f in (f1, *f2):         # slow workers: deadline fails
                with pytest.raises(TimeoutError):
                    f.result(timeout=30.0)

    def test_idle_fleet_pumps_immediately(self, operands):
        A, _, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        with CodedFleet(6, max_inflight=1, microbatch=True) as fleet:
            h = fleet.attach(plan)
            h.matvec(xs[0])                     # warm
            t0 = time.perf_counter()
            for i in range(8):                  # closed loop, empty queue
                h.matvec(xs[i])
            closed = (time.perf_counter() - t0) / 8
        # an idle fleet must not defer the pump: closed-loop latency
        # stays near the round time, not the watchdog tick (the old
        # inflight=1 pathology was ~50x the sequential shim)
        assert closed < 0.2
        assert all(r.calls == 1 for r in h.reports)
