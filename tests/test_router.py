"""Serve-router suite (repro.serve.router).

Covers: bitwise parity of routed calls vs direct ``PlanHandle`` calls
on all three transports (explicit-mask replay and race-mode observed-
pattern replay), weighted-fair stride determinism under a fixed seed
(two identical runs produce identical dispatch sequences, service
ratios track tenant weights), tenant isolation (deadline expiry and
shed-admission backpressure scoped to one tenant, the other's calls
untouched), the adaptive width feedback loop (ramps under backlog,
collapses when idle), live config push (``configure`` / ``swap_plan`` /
``add_replica`` / ``remove_replica`` without dropping traffic), the
``ServeEngine`` front-door integration (``CodedConfig.router``), and
shutdown hygiene (idempotent ``close``, ``unregister`` scoped to one
endpoint, no leaked scheduler/fleet/worker threads).
"""

import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import CodedFleet, compile_plan
from repro.api.fleet import FleetDegraded
from repro.serve import Router

TOL = dict(rtol=5e-3, atol=5e-3)
FLEET_THREADS = ("repro-router-sched", "coded-fleet", "cluster-worker",
                 "cluster-beat")


def block_sparse(rng, t, r, zeros, bs=8, dtype=np.float32):
    mask = rng.random((t // bs, r // bs)) >= zeros
    a = rng.standard_normal((t, r)).astype(dtype)
    return a * np.kron(mask, np.ones((bs, bs), dtype))


@pytest.fixture(scope="module")
def operands():
    rng = np.random.default_rng(7)
    t, r = 256, 144
    A = jnp.asarray(block_sparse(rng, t, r, 0.98))
    xs = jnp.asarray(rng.standard_normal((10, 4, t)), jnp.float32)
    return A, xs


@pytest.fixture(scope="module")
def plan(operands):
    A, _ = operands
    return compile_plan(A, scheme="proposed", n=6, s=2, backend="packed")


def leftover_threads():
    return [t.name for t in threading.enumerate()
            if t.name.startswith(FLEET_THREADS)]


# ---------------------------------------------------------------------------
# Parity: routed == direct PlanHandle, all transports
# ---------------------------------------------------------------------------


class TestRoutedParity:
    @pytest.mark.parametrize("transport", ["memory", "pipe", "tcp"])
    def test_explicit_mask_bitwise_vs_direct_handle(self, operands, plan,
                                                    transport):
        if transport != "memory":
            pytest.importorskip("scipy")
        A, xs = operands
        done = np.ones(6, bool)
        done[[1, 4]] = False
        with Router() as router, \
                CodedFleet(6, transport=transport) as ref_fleet:
            router.register("head", plan, replicas=1, n_workers=6,
                            transport=transport)
            h = ref_fleet.attach(plan)
            for i in range(3):
                routed = np.asarray(router.call("head", xs[i], done=done))
                direct = np.asarray(h.matvec(xs[i], done))
                np.testing.assert_array_equal(routed, direct)

    def test_race_mode_observed_pattern_bitwise(self, operands, plan):
        # batched race-mode calls carry their round's observed pattern
        # in fut.report; replaying it against a direct handle must
        # reproduce every routed result bit for bit
        A, xs = operands
        with Router() as router, CodedFleet(6) as ref_fleet:
            router.register("head", plan, replicas=1, n_workers=6)
            router.pause()
            futs = [router.submit("head", xs[i]) for i in range(6)]
            router.resume()
            outs = [np.asarray(f.result(30)) for f in futs]
            h = ref_fleet.attach(plan)
            for i, f in enumerate(futs):
                want = np.asarray(h.matvec(xs[i], done=f.report.pattern))
                np.testing.assert_array_equal(outs[i], want)

    def test_batched_calls_share_one_round(self, operands, plan):
        A, xs = operands
        with Router(batch_wait_s=0.05) as router:
            router.register("head", plan, replicas=1, n_workers=6,
                            adaptive=False, width=64)
            router.pause()
            futs = [router.submit("head", xs[i]) for i in range(5)]
            router.resume()
            [f.result(30) for f in futs]
            log = router.dispatch_log("head")
        assert len(log) == 1 and log[0]["calls"] == 5
        reports = {id(f.report) for f in futs}
        assert len(reports) == 1        # one fleet round served them all


# ---------------------------------------------------------------------------
# Weighted-fair scheduling
# ---------------------------------------------------------------------------


def run_contended(plan, xs, *, weights, calls=12):
    """Pause, queue `calls` per tenant, resume; return the dispatch
    sequence [(tenant, cols)...] and per-tenant resolved counts."""
    with Router(batch_wait_s=0.002) as router:
        router.register("head", plan, replicas=1, n_workers=6,
                        adaptive=False, width=8, max_inflight=2)
        for name, w in weights.items():
            router.set_tenant(name, weight=w)
        router.pause()
        futs = []
        for i in range(calls):
            for name in weights:
                futs.append(router.submit("head", xs[i % len(xs)],
                                          tenant=name))
        router.resume()
        [f.result(60) for f in futs]
        log = router.dispatch_log("head")
        m = router.metrics()["endpoints"]["head"]["tenants"]
    seq = [(e["tenant"], e["cols"]) for e in log]
    resolved = {t: v["counters"]["resolved"] for t, v in m.items()}
    return seq, resolved


class TestWeightedFair:
    def test_dispatch_sequence_deterministic(self, operands, plan):
        A, xs = operands
        seq1, res1 = run_contended(plan, xs, weights={"pro": 3.0,
                                                      "free": 1.0})
        seq2, res2 = run_contended(plan, xs, weights={"pro": 3.0,
                                                      "free": 1.0})
        assert seq1 == seq2             # stride order, batch widths
        assert res1 == res2 == {"pro": 12, "free": 12}

    def test_service_tracks_weights_under_contention(self, operands, plan):
        A, xs = operands
        seq, _ = run_contended(plan, xs, weights={"pro": 3.0, "free": 1.0},
                               calls=16)
        # while both tenants still queue, cumulative service converges
        # to the weight ratio (round granularity allows +-1 round)
        served = {"pro": 0, "free": 0}
        backlog = {"pro": 16 * 4, "free": 16 * 4}
        for tenant, cols in seq:
            if min(backlog.values()) <= 0:
                break
            served[tenant] += cols
            backlog[tenant] -= cols
        ratio = served["pro"] / max(served["free"], 1)
        assert 2.0 <= ratio <= 4.5, f"3:1 weights served {ratio:.2f}:1"

    def test_no_starvation_on_equal_weights(self, operands, plan):
        A, xs = operands
        seq, resolved = run_contended(plan, xs,
                                      weights={"a": 1.0, "b": 1.0})
        assert resolved == {"a": 12, "b": 12}
        # alternating stride: neither tenant dispatches 3 rounds in a
        # row while the other still queues
        tenants = [t for t, _ in seq]
        runs = max(len(list(g)) for _, g in __import__("itertools")
                   .groupby(tenants[:-2]))
        assert runs <= 2


# ---------------------------------------------------------------------------
# Tenant isolation
# ---------------------------------------------------------------------------


class TestTenantIsolation:
    def test_deadline_expiry_scoped_to_tenant(self, operands, plan):
        A, xs = operands
        with Router() as router:
            router.register("head", plan, replicas=1, n_workers=6)
            router.pause()                      # hold everything queued
            doomed = [router.submit("head", xs[i], tenant="slow",
                                    deadline=0.02) for i in range(3)]
            safe = [router.submit("head", xs[i], tenant="fast")
                    for i in range(3)]
            time.sleep(0.1)                     # the deadline passes
            router.resume()
            for f in doomed:
                with pytest.raises(TimeoutError):
                    f.result(30)
            for f in safe:                      # untouched neighbors
                np.testing.assert_allclose(
                    np.asarray(f.result(30)),
                    np.asarray(xs[safe.index(f)] @ A), **TOL)
            m = router.metrics()["endpoints"]["head"]["tenants"]
            assert m["slow"]["counters"]["deadline_hit"] == 3
            assert m["fast"]["counters"]["failed"] == 0

    def test_shed_admission_scoped_to_tenant(self, operands, plan):
        A, xs = operands
        with Router() as router:
            router.register("head", plan, replicas=1, n_workers=6)
            router.set_tenant("burst", queue_cap=2, admission="shed")
            router.pause()
            kept = [router.submit("head", xs[i], tenant="burst")
                    for i in range(2)]
            with pytest.raises(FleetDegraded) as ei:
                router.submit("head", xs[2], tenant="burst")
            assert ei.value.action == "shed"
            # the full neighbor never blocks the other tenant's lane
            other = router.submit("head", xs[3], tenant="steady")
            router.resume()
            for f in [*kept, other]:
                assert f.result(30) is not None
            m = router.metrics()["endpoints"]["head"]["tenants"]
            assert m["burst"]["counters"]["shed"] == 1
            assert m["steady"]["counters"]["resolved"] == 1

    def test_cancel_queued_call(self, operands, plan):
        A, xs = operands
        with Router() as router:
            router.register("head", plan, replicas=1, n_workers=6)
            router.pause()
            fut = router.submit("head", xs[0], tenant="t")
            assert fut.cancel()
            router.resume()
            assert fut.cancelled()
            m = router.metrics()["endpoints"]["head"]["tenants"]
            assert m["t"]["counters"]["cancelled"] == 1


# ---------------------------------------------------------------------------
# Adaptive microbatching feedback
# ---------------------------------------------------------------------------


class TestAdaptiveWidth:
    def test_width_ramps_under_backlog_and_collapses_idle(self, operands,
                                                          plan):
        A, xs = operands
        with Router(batch_wait_s=0.002) as router:
            router.register("head", plan, replicas=1, n_workers=6,
                            min_cols=1, max_cols=64)
            assert router.metrics()["endpoints"]["head"]["width"] == 1
            router.pause()
            futs = [router.submit("head", xs[i % len(xs)])
                    for i in range(24)]
            router.resume()
            [f.result(60) for f in futs]
            log = router.dispatch_log("head")
            grown = router.metrics()["endpoints"]["head"]["width"]
            assert grown > 1            # backlog pushed the width up
            assert max(e["cols"] for e in log) > 4
            for _ in range(8):          # idle: solo closed-loop calls
                router.call("head", xs[0])
            shrunk = router.metrics()["endpoints"]["head"]["width"]
            assert shrunk == 1          # collapsed, no collection window

    def test_static_width_is_frozen(self, operands, plan):
        A, xs = operands
        with Router() as router:
            router.register("head", plan, replicas=1, n_workers=6,
                            adaptive=False, width=8)
            router.pause()
            futs = [router.submit("head", xs[i % len(xs)])
                    for i in range(16)]
            router.resume()
            [f.result(60) for f in futs]
            assert router.metrics()["endpoints"]["head"]["width"] == 8
            assert all(e["cols"] <= 8 + 4        # one call may overshoot
                       for e in router.dispatch_log("head"))


# ---------------------------------------------------------------------------
# Metrics under sustained load (the autoscaler's sensor surface)
# ---------------------------------------------------------------------------


class TestMetricsUnderLoad:
    def test_backlog_width_and_latency_signals(self, operands, plan):
        """The exact fields ``repro.scale.router_sensor`` reads must
        hold up under a sustained burst: queued columns while paused,
        a drained queue + dispatch/latency evidence after."""
        A, xs = operands
        with Router(batch_wait_s=0.002) as router:
            router.register("head", plan, replicas=1, n_workers=6,
                            min_cols=1, max_cols=64)
            router.pause()
            futs = [router.submit("head", xs[i % len(xs)])
                    for i in range(24)]
            m = router.metrics()["endpoints"]["head"]
            cols = xs[0].shape[0]
            assert m["queued_cols"] == 24 * cols
            assert m["tenants"]["default"]["queued"] == 24
            assert m["tenants"]["default"]["queued_cols"] == 24 * cols
            (rep,) = m["replicas"]
            assert rep["dispatched"] == 0 and rep["lat_ewma_ms"] is None
            router.resume()
            [f.result(60) for f in futs]
            m = router.metrics()["endpoints"]["head"]
            assert m["queued_cols"] == 0
            assert m["width"] > 1           # backlog rode the adaptive loop
            assert m["depth_ewma"] > 0
            (rep,) = m["replicas"]
            assert rep["dispatched"] > 0
            assert rep["outstanding_cols"] == 0
            assert rep["lat_ewma_ms"] > 0   # the SLO policies' signal


# ---------------------------------------------------------------------------
# Config push without dropping traffic
# ---------------------------------------------------------------------------


class TestConfigPush:
    def test_configure_retunes_live(self, operands, plan):
        A, xs = operands
        with Router() as router:
            router.register("head", plan, replicas=1, n_workers=6,
                            adaptive=False, width=4)
            router.call("head", xs[0])
            router.configure("head", width=32, batch_wait_s=0.001)
            m = router.metrics()["endpoints"]["head"]
            assert m["width"] == 32 and m["batch_wait_s"] == 0.001

    def test_swap_plan_mid_traffic(self, operands, plan):
        A, xs = operands
        plan2 = compile_plan(A, scheme="cyclic31", n=6, s=2,
                             backend="packed")
        with Router() as router:
            router.register("head", plan, replicas=1, n_workers=6)
            router.pause()
            before = [router.submit("head", xs[i]) for i in range(3)]
            router.resume()
            router.swap_plan("head", plan2)
            after = [router.submit("head", xs[i]) for i in range(3)]
            for f in [*before, *after]:     # nothing dropped either side
                i = (before + after).index(f) % 3
                np.testing.assert_allclose(np.asarray(f.result(30)),
                                           np.asarray(xs[i] @ A), **TOL)

    def test_add_remove_replica_live(self, operands, plan):
        A, xs = operands
        with Router() as router:
            router.register("head", plan, replicas=1, n_workers=6)
            idx = router.add_replica("head", n_workers=6)
            futs = [router.submit("head", xs[i % len(xs)])
                    for i in range(12)]
            [f.result(30) for f in futs]
            assert len(router.metrics()["endpoints"]["head"]
                       ["replicas"]) == 2
            router.remove_replica("head", idx)
            m = router.metrics()["endpoints"]["head"]["replicas"]
            assert [r["index"] for r in m] == [0]
            np.testing.assert_allclose(       # survivor still serves
                np.asarray(router.call("head", xs[0])),
                np.asarray(xs[0] @ A), **TOL)

    def test_remove_last_replica_refuses(self, operands, plan):
        A, xs = operands
        with Router() as router:
            router.register("head", plan, replicas=1, n_workers=6)
            with pytest.raises(ValueError, match="last live replica"):
                router.remove_replica("head", 0)

    def test_replica_indices_monotonic_after_remove(self, operands, plan):
        # indices must never be reused: len(replicas) as the next index
        # would mint a duplicate after a middle replica is removed, and
        # remove_replica could then drain the wrong fleet
        A, xs = operands
        with Router() as router:
            router.register("head", plan, replicas=2, n_workers=6)
            assert router.add_replica("head", n_workers=6) == 2
            router.remove_replica("head", 1)
            assert router.add_replica("head", n_workers=6) == 3
            idxs = [r["index"] for r in
                    router.metrics()["endpoints"]["head"]["replicas"]]
            assert idxs == [0, 2, 3]
            router.remove_replica("head", 2)    # THE replica 2, not 3
            idxs = [r["index"] for r in
                    router.metrics()["endpoints"]["head"]["replicas"]]
            assert idxs == [0, 3]
            np.testing.assert_allclose(
                np.asarray(router.call("head", xs[0])),
                np.asarray(xs[0] @ A), **TOL)

    def test_replicas_balance_load(self, operands, plan):
        A, xs = operands
        with Router() as router:
            router.register("head", plan, replicas=2, n_workers=6,
                            adaptive=False, width=4, max_inflight=2)
            router.pause()
            futs = [router.submit("head", xs[i % len(xs)])
                    for i in range(16)]
            router.resume()
            [f.result(60) for f in futs]
            used = {e["replica"] for e in router.dispatch_log("head")}
            assert used == {0, 1}


# ---------------------------------------------------------------------------
# The scheduler thread never parks inside fleet admission
# ---------------------------------------------------------------------------


class TestNonBlockingDispatch:
    def test_backlog_wider_than_fleet_queue_cap_no_deadlock(self, operands,
                                                            plan):
        # regression: a batch wider than the fleet's queue_cap used to
        # acquire every admission slot then block the scheduler thread
        # on the next acquire forever (only its own unsubmitted calls
        # could free one) -- deadlocking the whole router.  Batches are
        # now clamped to the replica's free call budget.
        A, xs = operands
        with CodedFleet(6, queue_cap=8, max_inflight=2) as fleet, \
                Router() as router:
            router.register("head", plan, fleets=[fleet],
                            adaptive=False, width=256)
            router.pause()
            futs = [router.submit("head", xs[i % len(xs)])
                    for i in range(20)]
            router.resume()
            for i, f in enumerate(futs):
                np.testing.assert_allclose(
                    np.asarray(f.result(60)),
                    np.asarray(xs[i % len(xs)] @ A), **TOL)
            assert all(e["calls"] <= 8
                       for e in router.dispatch_log("head"))

    def test_saturated_endpoint_never_blocks_neighbors(self, operands,
                                                       plan):
        # head-of-line isolation: one endpoint's full replica queue
        # must not stall dispatching for other endpoints' tenants

        class FixedDelay:
            """Every task sleeps exactly 1.5s: long enough to hold the
            busy replica's budget through the assertion window, short
            enough that no worker thread outlives its fleet (an
            unbounded exponential sleeper would trip the global
            thread-leak check later in the suite)."""

            def delay(self, worker, task_row, work):
                return 1.5

            def should_fail(self, worker, tasks_done):
                return False

        A, xs = operands
        with CodedFleet(6, faults=FixedDelay(), queue_cap=4,
                        max_inflight=2, microbatch=False) as busy_fleet, \
                Router(batch_wait_s=0.002) as router:
            router.register("busy", plan, fleets=[busy_fleet],
                            adaptive=False, width=16)
            router.register("snappy", plan, replicas=1, n_workers=6)
            # saturate "busy": the first 4-call batch takes the whole
            # queue_cap and its slow round holds it for seconds
            stuck = [router.submit("busy", xs[i % len(xs)], deadline=5.0)
                     for i in range(12)]
            time.sleep(0.1)             # let the first batch dispatch
            # "snappy" must keep flowing while "busy" has zero budget
            np.testing.assert_allclose(
                np.asarray(router.call("snappy", xs[0], deadline=2.0)),
                np.asarray(xs[0] @ A), **TOL)
            for f in stuck:
                f.cancel()              # queued ones withdraw instantly
            for f in stuck:             # dispatched ones land or fail by
                try:                    # their 5s deadline -- either way
                    f.result(30)        # the backlog drains for close()
                except Exception:
                    pass

    def test_unregister_timeout_fails_leftovers_cleanly(self, operands,
                                                        plan):
        A, xs = operands
        with Router() as router:
            router.register("head", plan, replicas=1, n_workers=6)
            router.pause()              # nothing dispatches: drain must
            futs = [router.submit("head", xs[i], tenant="t")
                    for i in range(4)]  # ...time out with these queued
            router.unregister("head", timeout=0.2)
            for f in futs:              # the unregister error, never a
                with pytest.raises(RuntimeError, match="unregistered"):
                    f.result(5)         # bare cancellation
            assert router.endpoints() == []
            router.resume()             # flushed clean: the name is
            router.register("head", plan, replicas=1, n_workers=6)
            np.testing.assert_allclose(  # immediately reusable
                np.asarray(router.call("head", xs[0])),
                np.asarray(xs[0] @ A), **TOL)


# ---------------------------------------------------------------------------
# Engine front door + shutdown hygiene
# ---------------------------------------------------------------------------


class TestEngineFrontDoor:
    def test_engine_routes_coded_head_as_tenant(self):
        import jax  # noqa: PLC0415

        from repro.configs import get_smoke_config  # noqa: PLC0415
        from repro.configs.base import CodedConfig  # noqa: PLC0415
        from repro.models import build_model  # noqa: PLC0415
        from repro.serve import ServeEngine  # noqa: PLC0415

        cfg = get_smoke_config("qwen3-14b")
        model = build_model(cfg, dtype=jnp.float32)
        params = model.init(jax.random.key(0))
        router = Router()
        try:
            engine = ServeEngine(
                model, params, cfg, batch_size=2, max_len=32,
                coded=CodedConfig(enabled=True, n_workers=6, stragglers=2,
                                  router=router, tenant="engine"))
            assert router.has_endpoint("lm-head")
            head = (params["embed"].T if cfg.tie_embeddings
                    else params["head"])
            hidden = jnp.asarray(np.random.default_rng(0)
                                 .standard_normal((2, cfg.d_model)),
                                 jnp.float32)
            logits = engine.coded_logits(hidden)
            np.testing.assert_allclose(np.asarray(logits),
                                       np.asarray(hidden @ head), **TOL)
            m = router.metrics()["endpoints"]["lm-head"]["tenants"]
            assert m["engine"]["counters"]["resolved"] == 1
            engine.close()              # engine owns the endpoint...
            assert not router.has_endpoint("lm-head")
        finally:
            router.close()              # ...its builder owns the router

    def test_engine_register_race_falls_back_to_shared(self):
        # two engines' has_endpoint/register pairs are not atomic: the
        # loser's register raises -- it must fall back to sharing the
        # winner's endpoint, not crash engine construction
        import jax  # noqa: PLC0415

        from repro.configs import get_smoke_config  # noqa: PLC0415
        from repro.configs.base import CodedConfig  # noqa: PLC0415
        from repro.models import build_model  # noqa: PLC0415
        from repro.serve import ServeEngine  # noqa: PLC0415

        cfg = get_smoke_config("qwen3-14b")
        model = build_model(cfg, dtype=jnp.float32)
        params = model.init(jax.random.key(0))
        router = Router()
        try:
            winner = ServeEngine(
                model, params, cfg, batch_size=2, max_len=32,
                coded=CodedConfig(enabled=True, n_workers=6, stragglers=2,
                                  router=router))
            real = router.has_endpoint
            state = {"stale": True}

            def stale_once(name):       # the loser's pre-check snapshot
                if state.pop("stale", False):
                    return False
                return real(name)

            router.has_endpoint = stale_once
            try:
                loser = ServeEngine(
                    model, params, cfg, batch_size=2, max_len=32,
                    coded=CodedConfig(enabled=True, n_workers=6,
                                      stragglers=2, router=router))
            finally:
                router.has_endpoint = real
            head = (params["embed"].T if cfg.tie_embeddings
                    else params["head"])
            hidden = jnp.asarray(np.random.default_rng(1)
                                 .standard_normal((2, cfg.d_model)),
                                 jnp.float32)
            np.testing.assert_allclose(
                np.asarray(loser.coded_logits(hidden)),
                np.asarray(hidden @ head), **TOL)
            loser.close()               # shared mode: must NOT unregister
            assert router.has_endpoint("lm-head")
            winner.close()
            assert not router.has_endpoint("lm-head")
        finally:
            router.close()


class TestRouterLifecycle:
    def test_close_is_idempotent_and_leaks_nothing(self, operands, plan):
        A, xs = operands
        router = Router()
        router.register("head", plan, replicas=2, n_workers=6)
        futs = [router.submit("head", xs[i]) for i in range(4)]
        router.close()
        router.close()                  # second close is a no-op
        for f in futs:                  # drained, not dropped
            assert f.result(1) is not None
        time.sleep(0.3)
        assert leftover_threads() == []
        with pytest.raises(RuntimeError):
            router.submit("head", xs[0])

    def test_unregister_scoped_to_endpoint(self, operands, plan):
        A, xs = operands
        with Router() as router:
            router.register("head", plan, replicas=1, n_workers=6)
            router.register("aux", plan, replicas=1, n_workers=6)
            router.call("head", xs[0])
            router.unregister("head")
            assert router.endpoints() == ["aux"]
            with pytest.raises(ValueError, match="no endpoint"):
                router.submit("head", xs[0])
            np.testing.assert_allclose(     # the survivor keeps serving
                np.asarray(router.call("aux", xs[0])),
                np.asarray(xs[0] @ A), **TOL)

    def test_external_fleets_survive_router_close(self, operands, plan):
        A, xs = operands
        with CodedFleet(6) as fleet:
            router = Router()
            router.register("head", plan, fleets=[fleet])
            np.testing.assert_allclose(np.asarray(
                router.call("head", xs[0])), np.asarray(xs[0] @ A), **TOL)
            router.close()
            h = fleet.attach(plan)      # not closed by the router
            np.testing.assert_allclose(np.asarray(h.matvec(xs[0])),
                                       np.asarray(xs[0] @ A), **TOL)
