"""Suite-wide setup.

* Makes ``src`` importable when pytest runs from the repo root without
  PYTHONPATH (the tier-1 command sets it; direct IDE runs often don't).
* If the optional ``hypothesis`` dependency is missing, installs the
  deterministic fallback from ``tests/_hypothesis_compat.py`` under
  ``sys.modules`` so the six property-test modules still collect and
  run their seeded example sweeps instead of erroring out.
"""

from __future__ import annotations

import importlib.util
import sys
import types
from pathlib import Path

_HERE = Path(__file__).resolve().parent
_SRC = str(_HERE.parent / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


def _load_compat():
    spec = importlib.util.spec_from_file_location(
        "_hypothesis_compat", _HERE / "_hypothesis_compat.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


try:
    import hypothesis  # noqa: F401
except ImportError:
    _compat = _load_compat()

    _strategies = types.ModuleType("hypothesis.strategies")
    _strategies.integers = _compat.strategies.integers
    _strategies.lists = _compat.strategies.lists
    _strategies.data = _compat.strategies.data

    _shim = types.ModuleType("hypothesis")
    _shim.given = _compat.given
    _shim.settings = _compat.settings
    _shim.strategies = _strategies
    _shim.__is_repro_compat__ = True

    sys.modules["hypothesis"] = _shim
    sys.modules["hypothesis.strategies"] = _strategies
