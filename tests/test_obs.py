"""Observability suite (repro.obs): tracing, wire v5, attribution.

Covers: the disabled representation (no tracer -> zero events AND zero
extra wire fields, so a tracerless v5 peer decodes traced-era frames),
the clock handshake + segment decomposition (traced matvec rounds on
memory/pipe/tcp yield a span tree whose critical-chain segment sum
telescopes to the measured round wall), straggler attribution naming a
seeded slow worker and feeding compute rates into
``worker_capacities(rates=...)``, ring-buffer bounding via
``REPRO_TRACE_BUF``, the ``REPRO_TRACE`` env enabling the process
default, Chrome-trace/Prometheus export validity, and the dual-clock
fleet/router log stamps.
"""

import json
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import CodedFleet, compile_plan
from repro.cluster.faults import adversarial_faults
from repro.cluster.wire import Task, TaskResult, decode_event
from repro.obs import (
    Tracer,
    attribute,
    chrome_trace,
    default_tracer,
    prometheus_text,
    write_chrome_trace,
)


def block_sparse(rng, t, r, zeros, bs=8, dtype=np.float32):
    mask = rng.random((t // bs, r // bs)) >= zeros
    a = rng.standard_normal((t, r)).astype(dtype)
    return a * np.kron(mask, np.ones((bs, bs), dtype))


@pytest.fixture(scope="module")
def plan():
    rng = np.random.default_rng(5)
    A = jnp.asarray(block_sparse(rng, 128, 96, 0.9))
    return compile_plan(A, scheme="proposed", n=6, s=2, backend="packed")


@pytest.fixture(scope="module")
def xs():
    rng = np.random.default_rng(6)
    return [jnp.asarray(rng.standard_normal((4, 128)), jnp.float32)
            for _ in range(6)]


# ---------------------------------------------------------------------------
# disabled tracing: no events, no wire fields
# ---------------------------------------------------------------------------


class TestDisabled:
    def test_no_tracer_no_events(self, plan, xs, monkeypatch):
        monkeypatch.delenv("REPRO_TRACE", raising=False)
        assert default_tracer() is None
        with CodedFleet(6, transport="memory") as fleet:
            assert fleet._tracer is None
            h = fleet.attach(plan)
            for x in xs[:3]:
                h.matvec(x)
            for rnd_key in fleet._rounds:
                pytest.fail(f"round {rnd_key} still inflight")

    def test_untraced_frames_carry_no_trace_fields(self):
        t = Task(round=3, op="matvec", task_row=1, plan=2,
                 payload={"b": np.ones((4, 2), np.float32)})
        assert t.trace == 0
        assert Task.decode(t.encode()).trace == 0
        assert b"trace" not in t.encode()
        res = TaskResult(worker=1, round=3, task_row=1, plan=2,
                         arrays={"y": np.zeros(2, np.float32)})
        enc = res.encode()
        for fld in (b"trace", b"t_recv", b"t_start", b"t_finish"):
            assert fld not in enc
        back = decode_event(enc)
        assert back.trace == 0 and back.t_finish == 0.0

    def test_traced_frames_roundtrip(self):
        t = Task(round=3, op="matvec", task_row=1, plan=2, trace=77,
                 payload={"b": np.ones((4, 2), np.float32)})
        assert Task.decode(t.encode()).trace == 77
        res = TaskResult(worker=1, round=3, task_row=1, plan=2,
                         arrays={"y": np.zeros(2, np.float32)},
                         trace=77, t_recv=1.0, t_start=2.0,
                         t_finish=3.5)
        back = decode_event(res.encode())
        assert (back.trace, back.t_recv, back.t_start, back.t_finish) \
            == (77, 1.0, 2.0, 3.5)


# ---------------------------------------------------------------------------
# the tracer itself
# ---------------------------------------------------------------------------


class TestTracer:
    def test_ring_buffer_bounded(self):
        tr = Tracer(capacity=8)
        for i in range(50):
            tr.instant(f"e{i}")
        assert len(tr) == 8
        assert tr.events()[0]["name"] == "e42"      # oldest evicted

    def test_env_capacity(self, monkeypatch):
        monkeypatch.setenv("REPRO_TRACE_BUF", "16")
        assert Tracer().capacity == 16
        # garbage / nonpositive knobs fail loudly, naming the variable
        monkeypatch.setenv("REPRO_TRACE_BUF", "bogus")
        with pytest.raises(ValueError, match="REPRO_TRACE_BUF"):
            Tracer()
        monkeypatch.setenv("REPRO_TRACE_BUF", "0")
        with pytest.raises(ValueError, match="REPRO_TRACE_BUF"):
            Tracer()

    def test_env_enables_default(self, monkeypatch):
        import repro.obs.trace as trace_mod
        monkeypatch.setattr(trace_mod, "_GLOBAL", None)
        monkeypatch.delenv("REPRO_TRACE", raising=False)
        assert default_tracer() is None
        monkeypatch.setenv("REPRO_TRACE", "0")
        assert default_tracer() is None
        monkeypatch.setenv("REPRO_TRACE", "1")
        tr = default_tracer()
        assert tr is not None and default_tracer() is tr

    def test_span_and_wall_anchor(self):
        tr = Tracer(capacity=32)
        with tr.span("work", cat="test", meta=1):
            time.sleep(0.01)
        (e,) = tr.events()
        assert e["ph"] == "X" and e["dur"] >= 0.009
        assert e["args"] == {"meta": 1}
        wall = tr.wall_of(e["t"])
        assert abs(wall - time.time()) < 5.0


# ---------------------------------------------------------------------------
# traced rounds: span tree + segment telescoping on all transports
# ---------------------------------------------------------------------------


class TestTracedRounds:
    @pytest.mark.parametrize("transport", ["memory", "pipe", "tcp"])
    def test_segments_sum_to_round_wall(self, plan, xs, transport):
        if transport != "memory":
            pytest.importorskip("multiprocessing")
        tr = Tracer(capacity=4096)
        with CodedFleet(6, transport=transport, tracer=tr) as fleet:
            h = fleet.attach(plan)
            h.matvec(xs[0])                         # warm
            for x in xs:
                h.matvec(x)
        rounds = [e for e in tr.events() if e["cat"] == "round"]
        assert len(rounds) >= len(xs)
        devs = []
        for e in rounds[1:]:                        # skip the warm round
            segs = e["args"]["segments"]
            assert set(segs) == {"coord_queue", "wire_out",
                                 "worker_queue", "compute", "wire_back",
                                 "decode_wait", "decode"}
            wall = e["dur"]
            devs.append(abs(sum(segs.values()) - wall)
                        - max(0.10 * wall, 2e-3))
        assert len(devs) >= len(xs) - 1
        # clock-offset error (one-way hello latency) shows up in the
        # clamped segment sum; under parallel-suite load a single
        # round's offset can be noisy, so assert on the typical round
        # (the strict every-round 10% criterion runs in BENCH_obs)
        assert float(np.median(devs)) <= 0.0, devs
        # every traced round's spans share its trace id
        for e in rounds:
            tid = e["trace"]
            kin = [v for v in tr.events() if v["trace"] == tid]
            assert {v["name"] for v in kin} >= {"fleet.launch",
                                                "compute", "decode",
                                                "round"}

    def test_worker_spans_on_worker_tracks(self, plan, xs):
        tr = Tracer()
        with CodedFleet(6, tracer=tr) as fleet:
            h = fleet.attach(plan)
            h.matvec(xs[0])
        tracks = {e["track"] for e in tr.events()
                  if e["name"] == "compute"}
        assert tracks and all(t.startswith("worker-") for t in tracks)


# ---------------------------------------------------------------------------
# attribution
# ---------------------------------------------------------------------------


class TestAttribution:
    def test_names_seeded_slow_worker(self, plan, xs):
        slow = 3
        tr = Tracer()
        faults = adversarial_faults([slow], slowdown=60.0,
                                    time_scale=2e-3)
        with CodedFleet(6, transport="memory", faults=faults,
                        tracer=tr) as fleet:
            h = fleet.attach(plan)
            for x in xs * 2:
                h.matvec(x)
                # pacing: healthy workers drain their inboxes between
                # rounds, so only the injected straggler stays behind
                time.sleep(0.01)
            rep = attribute(tr.events())
            assert rep.rounds
            assert rep.suspects()[0] == slow
            s = rep.workers[slow]
            assert s.decoded_without + s.wasted_tasks > 0
            # attribution rates feed capacity quantization: the slow
            # worker must land on the lowest measured level
            rates = rep.compute_rates()
            if slow in rates:
                caps = fleet.worker_capacities(
                    workers=sorted(rep.workers), rates=rates)
                by_w = dict(zip(sorted(rep.workers), caps))
                assert by_w[slow] == min(caps)

    def test_wasted_and_decoded_without_accounting(self, plan, xs):
        tr = Tracer()
        with CodedFleet(6, tracer=tr) as fleet:
            h = fleet.attach(plan)
            for x in xs[:4]:
                h.matvec(x)
        rep = attribute(tr.events())
        # s=2 redundancy: every round decodes from k=4 of 6 workers, so
        # per round 2 workers are skipped or wasted
        assert sum(s.decoded_without + s.wasted_tasks
                   for s in rep.workers.values()) >= len(rep.rounds)
        assert rep.wasted_work() >= 0.0
        assert rep.table()      # renders without error

    def test_attribute_empty(self):
        rep = attribute([])
        assert rep.rounds == [] and rep.workers == {}
        assert rep.suspects() == []
        assert rep.compute_rates() == {}


# ---------------------------------------------------------------------------
# export
# ---------------------------------------------------------------------------


class TestExport:
    def test_chrome_trace_valid(self, plan, xs, tmp_path):
        tr = Tracer()
        with CodedFleet(6, tracer=tr) as fleet:
            h = fleet.attach(plan)
            h.matvec(xs[0])
            fleet._log_event("probe")   # exercise the log-merge path
            path = tmp_path / "trace.json"
            n = write_chrome_trace(str(path), tr, fleet=fleet)
        doc = json.loads(path.read_text())
        assert len(doc["traceEvents"]) == n > 0
        phases = {e["ph"] for e in doc["traceEvents"]}
        assert phases <= {"M", "X", "i"}
        for e in doc["traceEvents"]:
            assert "ts" in e or e["ph"] == "M"
            if e["ph"] == "X":
                assert e["dur"] >= 0.0
        names = {e["args"]["name"] for e in doc["traceEvents"]
                 if e["ph"] == "M" and e["name"] == "thread_name"}
        assert "fleet" in names and "fleet-log" in names

    def test_chrome_trace_empty(self):
        doc = chrome_trace([])
        assert json.loads(json.dumps(doc))["traceEvents"]

    def test_prometheus_text(self, plan, xs):
        tr = Tracer()
        with CodedFleet(6, tracer=tr) as fleet:
            h = fleet.attach(plan)
            h.matvec(xs[0])
            text = prometheus_text(fleet=fleet, tracer=tr)
        assert "repro_fleet_n_live 6" in text
        assert "repro_trace_buffer_capacity" in text
        for line in text.strip().splitlines():
            name, val = line.rsplit(" ", 1)
            float(val)          # every exposition line is name value


# ---------------------------------------------------------------------------
# dual-clock log stamps (satellites a+b)
# ---------------------------------------------------------------------------


class TestDualClockLogs:
    def test_fleet_event_log_stamps_both_clocks(self, plan):
        with CodedFleet(6) as fleet:
            fleet.attach(plan)
            fleet._log_event("probe", detail=1)
            recs = [e for e in fleet.event_log if e["kind"] == "probe"]
        (e,) = recs
        assert abs(e["t"] - time.time()) < 5.0
        assert abs(e["t_mono"] - time.perf_counter()) < 5.0

    def test_router_dispatch_log_stamps_both_clocks(self, plan, xs):
        from repro.serve.router import Router
        router = Router()
        try:
            router.register("head", plan, replicas=1, n_workers=6)
            router.call("head", xs[0], tenant="t")
            log = router.dispatch_log("head")
        finally:
            router.close()
        assert log
        e = log[-1]
        assert {"t", "t_mono", "tenant", "cols", "calls", "width",
                "replica", "endpoint"} <= set(e)
        assert abs(e["t"] - time.time()) < 5.0
        assert abs(e["t_mono"] - time.perf_counter()) < 5.0
