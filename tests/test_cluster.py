"""Cluster runtime suite (repro.cluster).

Covers: the wire codec (record framing, version gate, truncation /
garbling robustness, dtype fidelity for f32/bf16 operands -- the
serialization mirror of ``_match_dtype``), plan serialization
round-trips for every registered scheme, shard partitioning with input
column supports, dispatcher parity against the in-process plan under
all C(n, s) whole-worker patterns (bitwise on the packed backend, over
all three transports: memory, pipe, tcp) and under partial-straggler
task-level patterns, race-mode correctness with latency injection,
heartbeat-driven liveness (missed beats -> suspected -> requeue; a
worker killed mid-round over tcp), the tcp handshake's wire-version
gate, transport shutdown hygiene (no leaked fds/threads), worker
fail-stop with requeue, fault-injector determinism (including ``Hang``),
bytes-on-wire accounting, serve-engine mask routing, the scheme-registry
CLI, and online plan re-tuning (``plan.retune`` + shard re-shipping +
trainer integration).
"""

import itertools
import os
import signal
import socket
import struct
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import compile_plan, list_schemes, make_scheme
from repro.cluster import (
    ClusterPlan,
    FailStop,
    Hang,
    NoFaults,
    StragglerFaults,
    adversarial_faults,
    dumps_plan,
    loads_plan,
    resolve_transport,
    shard_plan,
    straggler_mask,
)
from repro.cluster.faults import from_spec
from repro.cluster.wire import (
    WIRE_VERSION,
    Heartbeat,
    Task,
    TaskResult,
    decode_event,
    decode_record,
    decode_record_sg,
    encode_record,
    encode_record_sg,
    flatten,
    record_nbytes,
    scheme_from_meta,
    scheme_to_meta,
)
from repro.core.straggler import AdversarialSlow

TOL = dict(rtol=5e-3, atol=5e-3)


def block_sparse(rng, t, r, zeros, bs=8, dtype=np.float32):
    mask = rng.random((t // bs, r // bs)) >= zeros
    a = rng.standard_normal((t, r)).astype(dtype)
    return a * np.kron(mask, np.ones((bs, bs), dtype))


def all_straggler_masks(n, s):
    for pat in itertools.combinations(range(n), s):
        done = np.ones(n, bool)
        done[list(pat)] = False
        yield done


@pytest.fixture(scope="module")
def sparse_operand():
    rng = np.random.default_rng(0)
    t, r = 256, 144
    A = jnp.asarray(block_sparse(rng, t, r, 0.98))
    x = jnp.asarray(rng.standard_normal((3, t)), jnp.float32)
    return A, x


# ---------------------------------------------------------------------------
# Wire codec
# ---------------------------------------------------------------------------


class TestWireCodec:
    def test_record_roundtrip(self):
        meta = {"a": 1, "s": "x", "nested": {"b": [1, 2]}}
        arrays = {"f": np.arange(6, dtype=np.float32).reshape(2, 3),
                  "i": np.asarray([3, 1], np.int32),
                  "d": np.ones((2, 2), np.float64)}
        m2, a2 = decode_record(encode_record(meta, arrays))
        assert m2 == meta
        for k, v in arrays.items():
            assert a2[k].dtype == v.dtype
            np.testing.assert_array_equal(a2[k], v)

    def test_bad_magic_and_version_rejected(self):
        blob = bytearray(encode_record({"x": 1}, {}))
        bad = b"XXXX" + bytes(blob[4:])
        with pytest.raises(ValueError, match="not a repro"):
            decode_record(bad)
        blob[4] = 0xFF                      # version field
        with pytest.raises(ValueError, match="version"):
            decode_record(bytes(blob))

    def test_truncated_frames_rejected(self):
        blob = encode_record({"x": 1}, {"a": np.arange(8, dtype=np.float32)})
        with pytest.raises(ValueError, match="truncated"):
            decode_record(blob[:6])                 # short header
        with pytest.raises(ValueError, match="truncated"):
            decode_record(blob[:20])                # manifest cut off
        with pytest.raises(ValueError, match="truncated"):
            decode_record(blob[:-4])                # array payload cut off

    def test_garbled_manifest_rejected(self):
        blob = bytearray(encode_record({"x": 1}, {}))
        # flip bytes inside the json manifest
        blob[14 + 2: 14 + 8] = b"\xff\xfe\xfd\xfc\xfb\xfa"
        with pytest.raises(ValueError, match="garbled|truncated"):
            decode_record(bytes(blob))

    def test_sg_roundtrip_and_flatten_equivalence(self):
        # wire v6 scatter/gather: (header, buffers) framing must be
        # byte-equivalent to the flat encoding, and decoding the
        # buffer list must view, not copy, the source arrays
        meta = {"record": "task", "round": 9, "nested": {"b": [1, 2]}}
        arrays = {"f": np.arange(6, dtype=np.float32).reshape(2, 3),
                  "i": np.asarray([3, 1], np.int32)}
        header, bufs = encode_record_sg(meta, arrays)
        assert flatten(header, bufs) == encode_record(meta, arrays)
        assert flatten(header, bufs, prefix=b"LEN!") == \
            b"LEN!" + encode_record(meta, arrays)
        m2, a2 = decode_record_sg(header, bufs)
        assert m2 == meta
        for k, v in arrays.items():
            assert a2[k].dtype == v.dtype
            np.testing.assert_array_equal(a2[k], v)
        assert np.shares_memory(a2["f"], arrays["f"])   # zero-copy views
        # and the flat decoder accepts the gathered frame unchanged
        m3, a3 = decode_record(flatten(header, bufs))
        assert m3 == meta
        np.testing.assert_array_equal(a3["i"], arrays["i"])

    def test_sg_wrong_buffer_count_rejected(self):
        arrays = {"a": np.ones(4, np.float32), "b": np.arange(3, dtype=np.int64)}
        header, bufs = encode_record_sg({"x": 1}, arrays)
        with pytest.raises(ValueError, match="wrong buffer count"):
            decode_record_sg(header, bufs[:1])
        with pytest.raises(ValueError, match="wrong buffer count"):
            decode_record_sg(header, [*bufs, memoryview(b"extra")])
        with pytest.raises(ValueError, match="wrong buffer count"):
            decode_record_sg(header, [])

    def test_sg_truncated_buffers_rejected(self):
        arrays = {"a": np.ones(4, np.float32), "b": np.arange(3, dtype=np.int64)}
        header, bufs = encode_record_sg({"x": 1}, arrays)
        with pytest.raises(ValueError, match="truncated"):
            decode_record_sg(header, [bufs[0][:-2], bufs[1]])
        with pytest.raises(ValueError, match="truncated"):
            decode_record_sg(header, [bufs[0], bufs[1][:4]])
        # buffer lengths are checked per array, by name, in the error
        with pytest.raises(ValueError, match="'b'"):
            decode_record_sg(header, [bufs[0], bufs[1][:4]])

    def test_sg_garbled_header_rejected(self):
        header, bufs = encode_record_sg({"x": 1},
                                        {"a": np.ones(4, np.float32)})
        bad = bytearray(header)
        bad[0:4] = b"XXXX"
        with pytest.raises(ValueError, match="not a repro"):
            decode_record_sg(bytes(bad), bufs)
        bad = bytearray(header)
        bad[4] = WIRE_VERSION + 1               # wrong-wire-version peer
        with pytest.raises(ValueError, match="version"):
            decode_record_sg(bytes(bad), bufs)
        with pytest.raises(ValueError, match="truncated"):
            decode_record_sg(header[:6], bufs)  # short header
        bad = bytearray(header)
        bad[16:22] = b"\xff\xfe\xfd\xfc\xfb\xfa"    # inside the manifest
        with pytest.raises(ValueError, match="garbled|truncated"):
            decode_record_sg(bytes(bad), bufs)

    def test_sg_task_and_result_frames(self):
        t = Task(round=3, op="matvec", task_row=5,
                 payload={"b": np.ones((4, 2), np.float32)},
                 meta={"b": 2})
        header, bufs = t.encode_sg()
        assert flatten(header, bufs) == t.encode()
        t2 = Task.decode(flatten(header, bufs))
        np.testing.assert_array_equal(t2.payload["b"], t.payload["b"])
        r = TaskResult(worker=1, round=3, task_row=5, copied=123,
                       arrays={"y": np.zeros(3, np.float32)})
        header, bufs = r.encode_sg()
        assert flatten(header, bufs) == r.encode()
        r2 = TaskResult.decode(r.encode())
        assert r2.copied == 123                 # v6 copy accounting rides
        r0 = TaskResult(worker=1, round=3, task_row=5,
                        arrays={"y": np.zeros(3, np.float32)})
        assert b"copied" not in r0.encode()     # ...only when nonzero
        assert TaskResult.decode(r0.encode()).copied == 0

    def test_structurally_garbled_records_rejected(self):
        import json

        # manifest parses as json but the array specs are missing
        # fields: still ValueError, never a KeyError escaping handlers
        head = json.dumps({"meta": {}, "arrays": [{}]}).encode()
        blob = struct.pack("<4sHQ", b"RPRC", WIRE_VERSION, len(head)) + head
        with pytest.raises(ValueError, match="garbled"):
            decode_record(blob)
        # an event record that parses but lacks required fields
        with pytest.raises(ValueError, match="garbled"):
            decode_event(encode_record({"record": "result"}))
        with pytest.raises(ValueError, match="garbled"):
            decode_event(encode_record({"record": "beat"}))

    def test_record_nbytes_exact(self):
        meta = {"record": "task", "round": 2, "op": "matvec",
                "task_row": 7, "meta": {"b": 3}}
        arrays = {"bx": np.ones((16, 3), np.float32),
                  "bi": np.arange(2, dtype=np.int32)}
        assert record_nbytes(meta, arrays) == len(encode_record(meta, arrays))
        t = Task(round=2, op="matvec", task_row=7, payload=arrays,
                 meta={"b": 3})
        assert t.nbytes() == len(t.encode())

    def test_heartbeat_and_event_demux(self):
        hb = Heartbeat(worker=3, tick=17)
        back = decode_event(hb.encode())
        assert isinstance(back, Heartbeat)
        assert (back.worker, back.tick) == (3, 17)
        res = TaskResult(worker=1, round=2, task_row=4,
                         arrays={"y": np.ones(2, np.float32)})
        back = decode_event(res.encode())
        assert isinstance(back, TaskResult) and back.task_row == 4
        with pytest.raises(ValueError, match="unexpected event"):
            decode_event(encode_record({"record": "task"}))

    def test_task_result_roundtrip(self):
        t = Task(round=3, op="matvec", task_row=5,
                 payload={"b": np.ones((4, 2), np.float32)},
                 meta={"b": 2})
        t2 = Task.decode(t.encode())
        assert (t2.round, t2.op, t2.task_row, t2.meta) == (3, "matvec", 5,
                                                           {"b": 2})
        np.testing.assert_array_equal(t2.payload["b"], t.payload["b"])
        r = TaskResult(worker=1, round=3, task_row=5, work=0.25,
                       compute_s=1e-4, arrays={"y": np.zeros(3, np.float32)})
        r2 = TaskResult.decode(r.encode())
        assert r2.ok and r2.kind == "result" and r2.work == 0.25
        np.testing.assert_array_equal(r2.arrays["y"], r.arrays["y"])

    def test_scheme_meta_roundtrip_all_schemes(self):
        for info in list_schemes():
            if info.hetero:
                sch = make_scheme(info.name, capacities=[2, 2, 1, 1], k_A=4)
            elif info.kind == "mv":
                sch = make_scheme(info.name, n=6, k_A=4)
            else:
                sch = make_scheme(info.name, n=6, k_A=2, k_B=2)
            assert scheme_from_meta(scheme_to_meta(sch)) == sch


# ---------------------------------------------------------------------------
# Plan serialization
# ---------------------------------------------------------------------------


class TestPlanSerialization:
    @pytest.mark.parametrize("backend", ["packed", "reference"])
    def test_mv_roundtrip_every_scheme(self, backend):
        rng = np.random.default_rng(1)
        t, r = 128, 96
        A = jnp.asarray(block_sparse(rng, t, r, 0.9))
        x = jnp.asarray(rng.standard_normal(t), jnp.float32)
        for info in list_schemes("mv"):
            if info.hetero:
                plan = compile_plan(A, scheme=info.name,
                                    capacities=[2, 2, 1, 1], k_A=4,
                                    backend=backend)
            else:
                plan = compile_plan(A, scheme=info.name, n=6, k_A=4,
                                    backend=backend)
            plan2 = loads_plan(dumps_plan(plan))
            assert plan2.scheme == plan.scheme
            assert plan2.backend == plan.backend
            np.testing.assert_array_equal(np.asarray(plan2.G),
                                          np.asarray(plan.G))
            np.testing.assert_array_equal(np.asarray(plan2.executor.coded),
                                          np.asarray(plan.executor.coded))
            np.testing.assert_array_equal(np.asarray(plan2.matvec(x)),
                                          np.asarray(plan.matvec(x)))

    def test_mm_roundtrip(self):
        rng = np.random.default_rng(2)
        t, r = 128, 64
        A = jnp.asarray(block_sparse(rng, t, r, 0.9))
        B = jnp.asarray(rng.standard_normal((t, 24)), jnp.float32)
        for name in ("proposed", "poly"):
            plan = compile_plan(A, scheme=name, n=6, k_A=2, k_B=2,
                                backend="packed")
            plan2 = loads_plan(dumps_plan(plan))
            np.testing.assert_array_equal(np.asarray(plan2.matmat(B)),
                                          np.asarray(plan.matmat(B)))

    def test_aggregation_only_roundtrip(self):
        plan = compile_plan(scheme="proposed", n=6, s=2, seed=3)
        plan2 = loads_plan(dumps_plan(plan))
        rng = np.random.default_rng(3)
        payloads = [jnp.asarray(rng.standard_normal(5), jnp.float32)
                    for _ in range(6)]
        done = np.ones(6, bool)
        done[4] = False
        np.testing.assert_allclose(
            np.asarray(plan2.aggregate(payloads, jnp.asarray(done))),
            np.asarray(plan.aggregate(payloads, jnp.asarray(done))), **TOL)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_fidelity(self, dtype):
        # the wire mirror of api.plan._match_dtype: a bf16 operand's
        # coded shards must come back bf16, not silently doubled to f32
        rng = np.random.default_rng(4)
        A = jnp.asarray(block_sparse(rng, 64, 48, 0.9)).astype(dtype)
        for backend in ("packed", "reference"):
            plan = compile_plan(A, scheme="proposed", n=6, s=2,
                                backend=backend)
            assert plan.executor.coded.dtype == dtype
            plan2 = loads_plan(dumps_plan(plan))
            assert plan2.executor.coded.dtype == dtype
            np.testing.assert_array_equal(
                np.asarray(plan2.executor.coded, np.float32),
                np.asarray(plan.executor.coded, np.float32))

    def test_cache_patterns_shipped(self):
        rng = np.random.default_rng(5)
        A = jnp.asarray(block_sparse(rng, 64, 48, 0.98))
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        done = np.ones(6, bool)
        done[[0, 3]] = False
        plan.prewarm(jnp.asarray(done))
        plan2 = loads_plan(dumps_plan(plan))
        cache = plan2._decode_cache()
        hits0 = cache.hits
        plan2.matvec(jnp.ones(64, jnp.float32), jnp.asarray(done))
        assert cache.hits == hits0 + 1      # pattern arrived pre-warmed

    def test_shard_partition(self, sparse_operand):
        A, _ = sparse_operand
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        for w in (6, 4, 3, 1):
            shards = shard_plan(plan, w)
            rows = sorted(r for s in shards for r in s.task_rows)
            assert rows == list(range(plan.n_tasks))
            assert all(s.work and min(s.work) > 0 for s in shards)
            assert len(shards) == w
        with pytest.raises(ValueError, match="n_workers"):
            shard_plan(plan, 0)


# ---------------------------------------------------------------------------
# Dispatcher parity vs the in-process plan
# ---------------------------------------------------------------------------


class TestDispatcherParity:
    @pytest.mark.parametrize("scheme", ["proposed", "cyclic31"])
    def test_whole_worker_patterns_bitwise(self, sparse_operand, scheme):
        A, x = sparse_operand
        n, s = 6, 2
        plan = compile_plan(A, scheme=scheme, n=n, s=s, backend="packed")
        with plan.to_cluster() as cl:
            assert cl.transport_name == "memory"
            for done in all_straggler_masks(n, s):
                want = np.asarray(plan.matvec(x, jnp.asarray(done)))
                got = np.asarray(cl.matvec(x, done))
                # same BSR products, same cached inverse: bitwise equal
                np.testing.assert_array_equal(got, want)

    @pytest.mark.slow
    @pytest.mark.parametrize("transport", ["pipe", "tcp", "shm"])
    def test_whole_worker_patterns_bitwise_socket_transports(
            self, sparse_operand, transport):
        # the same C(6, 2) sweep over real process/socket transports:
        # parity is a property of the stack, not of one byte carrier
        A, x = sparse_operand
        n, s = 6, 2
        plan = compile_plan(A, scheme="proposed", n=n, s=s, backend="packed")
        with plan.to_cluster(transport=transport) as cl:
            assert cl.transport_name == transport
            if transport == "tcp":
                # every worker digest-verified its shard and acked it
                import hashlib

                want_acks = {w: hashlib.sha256(blob).hexdigest()
                             for w, blob in enumerate(cl._shard_bytes)}
                deadline = time.time() + 10
                while (cl.transport.shard_acks != want_acks
                       and time.time() < deadline):
                    time.sleep(0.02)
                assert cl.transport.shard_acks == want_acks
            for done in all_straggler_masks(n, s):
                want = np.asarray(plan.matvec(x, jnp.asarray(done)))
                got = np.asarray(cl.matvec(x, done))
                np.testing.assert_array_equal(got, want)

    def test_reference_backend_tolerance(self, sparse_operand):
        A, x = sparse_operand
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="reference")
        done = np.ones(6, bool)
        done[[1, 4]] = False
        with plan.to_cluster() as cl:
            got = np.asarray(cl.matvec(x, done))
        np.testing.assert_allclose(
            got, np.asarray(plan.matvec(x, jnp.asarray(done))), **TOL)

    def test_partial_straggler_task_level_parity(self, sparse_operand):
        # scs36: 6 workers x 3 tasks, decode needs 12 of 18 task rows.
        # Worker 0 finishes 2/3, worker 1 finishes 1/3 -- strict subsets.
        A, x = sparse_operand
        plan = compile_plan(A, scheme="scs36", n=6, k_A=4, backend="packed")
        per = plan.tasks_per_worker
        assert per == 3
        task_done = np.ones(plan.n_tasks, bool)
        task_done[[2, 4, 5]] = False        # w0 loses row 2, w1 rows 4, 5
        want = np.asarray(plan.matvec(x, jnp.asarray(task_done)))
        with plan.to_cluster() as cl:
            got = np.asarray(cl.matvec(x, task_done))
            rep = cl.last_report
        np.testing.assert_array_equal(got, want)
        assert 0 in rep.partial_workers and 1 in rep.partial_workers
        # ground truth: still the exact matvec
        np.testing.assert_allclose(got, np.asarray(x @ A), **TOL)

    def test_fewer_hosts_than_virtual_workers(self, sparse_operand):
        A, x = sparse_operand
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        done = np.ones(6, bool)
        done[[3, 4]] = False                # host 0 keeps row 0, loses 3
        with plan.to_cluster(3) as cl:      # hosts own {0,3}, {1,4}, {2,5}
            got = np.asarray(cl.matvec(x, done))
            rep = cl.last_report
        np.testing.assert_array_equal(
            got, np.asarray(plan.matvec(x, jnp.asarray(done))))
        assert rep.partial_workers == (0, 1)

    def test_matmat_patterns(self, sparse_operand):
        A, _ = sparse_operand
        rng = np.random.default_rng(6)
        B = jnp.asarray(rng.standard_normal((A.shape[0], 24)), jnp.float32)
        n, ka, kb = 6, 2, 2
        plan = compile_plan(A, scheme="proposed", n=n, k_A=ka, k_B=kb,
                            backend="packed")
        with plan.to_cluster() as cl:
            for done in itertools.islice(all_straggler_masks(n, 2), 6):
                want = np.asarray(plan.matmat(B, jnp.asarray(done)))
                got = np.asarray(cl.matmat(B, done))
                np.testing.assert_array_equal(got, want)
            got = np.asarray(cl.matmat(B))          # race mode
        np.testing.assert_allclose(got, np.asarray(A.T @ B), **TOL)

    def test_race_mode_with_faults(self, sparse_operand):
        A, x = sparse_operand
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        faults = StragglerFaults(time_scale=2e-3, seed=11)
        with plan.to_cluster(faults=faults) as cl:
            for _ in range(4):
                got = np.asarray(cl.matvec(x))
                np.testing.assert_allclose(got, np.asarray(x @ A), **TOL)
                assert cl.last_report.n_done >= plan.k

    def test_matvec_1d_and_aggregation_only_errors(self, sparse_operand):
        A, x = sparse_operand
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        with plan.to_cluster() as cl:
            got = np.asarray(cl.matvec(x[0]))
            assert got.shape == (plan.r,)
            with pytest.raises(ValueError, match="matmat needs an mm"):
                cl.matmat(x)
            with pytest.raises(ValueError, match="need at least k"):
                cl.matvec(x, np.zeros(6, bool))
        agg = compile_plan(scheme="proposed", n=6, s=2)
        with agg.to_cluster() as cl:
            with pytest.raises(ValueError, match="aggregation-only"):
                cl.matvec(x)

    def test_aggregate_parity_and_race(self):
        rng = np.random.default_rng(7)
        plan = compile_plan(scheme="proposed", n=6, s=2, seed=1)
        k = plan.k
        # consistent coded payloads (payload_i = sum_q G[i,q] g_q):
        # only then is the decode row-set independent, which is what
        # race mode exercises (arrival order picks the rows)
        G = np.asarray(plan.G, np.float32)
        grads = [rng.standard_normal((4, 3)).astype(np.float32)
                 for _ in range(k)]
        payloads = [{"g": jnp.asarray(
            sum(G[i, q] * grads[q] for q in range(k)))} for i in range(6)]
        total = np.sum(grads, axis=0)
        done = np.ones(6, bool)
        done[2] = False
        want = np.asarray(plan.aggregate(payloads, jnp.asarray(done))["g"])
        with plan.to_cluster() as cl:
            got = np.asarray(cl.aggregate(payloads, done)["g"])
            np.testing.assert_allclose(got, want, **TOL)
            raced = np.asarray(cl.aggregate(payloads)["g"])
        np.testing.assert_allclose(raced, total, **TOL)

    def test_coded_aggregator_cluster_mode(self):
        from repro.parallel.coded_grads import CodedAggregator

        rng = np.random.default_rng(8)
        agg = CodedAggregator.build(6, 2, seed=1)
        k = agg.scheme.k_A
        shard_grads = [{"w": jnp.asarray(rng.standard_normal((3, 2)),
                                         jnp.float32)} for _ in range(k)]
        payloads = [agg.worker_payload(i, shard_grads) for i in range(6)]
        done = np.ones(6, bool)
        done[5] = False
        want = np.asarray(agg.aggregate(payloads, jnp.asarray(done))["w"])
        with agg.to_cluster() as cl:
            got = np.asarray(agg.aggregate(payloads, done, cluster=cl)["w"])
        np.testing.assert_allclose(got, want, **TOL)
        total = np.sum([np.asarray(g["w"], np.float32)
                        for g in shard_grads], axis=0)
        np.testing.assert_allclose(got, total, **TOL)


# ---------------------------------------------------------------------------
# Fail-stop, requeue, deadlines, process backend
# ---------------------------------------------------------------------------


class TestFailStopAndTransports:
    def test_failstop_requeues_and_recovers(self, sparse_operand):
        A, x = sparse_operand
        n, k = 6, 5
        plan = compile_plan(A, scheme="proposed", n=n, s=n - k,
                            backend="packed")
        # two deaths leave 4 live hosts < k: decode NEEDS the requeue
        with plan.to_cluster(faults=FailStop({0: 0, 3: 0})) as cl:
            got = np.asarray(cl.matvec(x))
            rep = cl.last_report
            assert rep.deaths == 2
            assert rep.requeues >= 1
            np.testing.assert_allclose(got, np.asarray(x @ A), **TOL)
            # the cluster keeps serving on the survivors
            got = np.asarray(cl.matvec(x))
            assert cl.last_report.deaths == 0
            np.testing.assert_allclose(got, np.asarray(x @ A), **TOL)

    def test_sequential_deaths_reship_inherited_shards(self,
                                                       sparse_operand):
        # worker 0 dies first; its shard is inherited by some heir.  When
        # THAT heir later dies, its successor must receive both shards --
        # the inherited task rows must never be stranded.
        A, x = sparse_operand
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        with plan.to_cluster(faults=FailStop({0: 0, 1: 1})) as cl:
            for i in range(4):          # worker 1 dies mid-sequence
                got = np.asarray(cl.matvec(x))
                np.testing.assert_allclose(got, np.asarray(x @ A), **TOL)
            assert sum(r.deaths for r in cl.reports) == 2

    def test_all_workers_dead_raises(self, sparse_operand):
        A, x = sparse_operand
        plan = compile_plan(A, scheme="proposed", n=6, s=1,
                            backend="packed")
        with plan.to_cluster(faults=FailStop(
                {w: 0 for w in range(6)})) as cl:
            with pytest.raises(RuntimeError, match="dead"):
                cl.matvec(x)

    def test_deadline_timeout(self, sparse_operand):
        A, x = sparse_operand
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        slow = StragglerFaults(time_scale=30.0, seed=1)   # ~minutes/task
        with plan.to_cluster(faults=slow, deadline=0.3) as cl:
            with pytest.raises(TimeoutError, match="deadline"):
                cl.matvec(x)

    @pytest.mark.slow
    def test_process_backend_parity(self, sparse_operand):
        A, x = sparse_operand
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        done = np.ones(6, bool)
        done[[1, 4]] = False
        want = np.asarray(plan.matvec(x, jnp.asarray(done)))
        # backend="process" is the legacy spelling of transport="pipe"
        with plan.to_cluster(3, backend="process") as cl:
            assert cl.transport_name == "pipe"
            got = np.asarray(cl.matvec(x, done))
        # same f32 BSR math on the far side of the pipe
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Heartbeat liveness, tcp handshake, transport hygiene
# ---------------------------------------------------------------------------


class TestLivenessAndTcp:
    def test_memory_hang_suspected_and_requeued(self, sparse_operand):
        # n=6, k=5: two silent workers leave 4 live -- decode NEEDS the
        # heartbeat timeout -> suspected -> requeue sequencing.  No
        # done= mask anywhere: liveness is measured, not injected.
        A, x = sparse_operand
        plan = compile_plan(A, scheme="proposed", n=6, s=1,
                            backend="packed")
        with plan.to_cluster(faults=Hang({0: 0, 3: 0}), heartbeat_s=0.05,
                             suspect_after=0.4) as cl:
            got = np.asarray(cl.matvec(x))
            rep = cl.last_report
            # one requeued row can complete the decode before the
            # second hung worker crosses the timeout: 1 or 2 suspected
            assert 1 <= rep.suspected <= 2
            assert rep.deaths == 0              # silent, not fail-stop
            assert rep.requeues >= 1
            np.testing.assert_allclose(got, np.asarray(x @ A), **TOL)
            # the cluster keeps serving on the survivors
            got = np.asarray(cl.matvec(x))
            assert cl.last_report.suspected == 0
            np.testing.assert_allclose(got, np.asarray(x @ A), **TOL)

    @pytest.mark.slow
    def test_tcp_hang_suspected_and_requeued(self, sparse_operand):
        # same sequencing over real sockets: the hung child keeps its
        # connection open, so ONLY the heartbeat timeout can catch it
        A, x = sparse_operand
        plan = compile_plan(A, scheme="proposed", n=6, s=1,
                            backend="packed")
        with plan.to_cluster(transport="tcp", faults=Hang({2: 0, 4: 0}),
                             heartbeat_s=0.05, suspect_after=0.4) as cl:
            got = np.asarray(cl.matvec(x))
            rep = cl.last_report
            assert 1 <= rep.suspected <= 2 and rep.requeues >= 1
            np.testing.assert_allclose(got, np.asarray(x @ A), **TOL)

    @pytest.mark.slow
    def test_tcp_worker_killed_mid_round(self, sparse_operand):
        # a worker SIGKILLed between rounds: the dropped connection
        # surfaces as a death, its shard is re-shipped, the decode is
        # still correct -- no fault injection, no done= mask
        A, x = sparse_operand
        plan = compile_plan(A, scheme="proposed", n=6, s=1,
                            backend="packed")
        with plan.to_cluster(transport="tcp") as cl:
            np.testing.assert_allclose(np.asarray(cl.matvec(x)),
                                       np.asarray(x @ A), **TOL)
            os.kill(cl.transport._procs[2].pid, signal.SIGKILL)
            time.sleep(0.3)
            got = np.asarray(cl.matvec(x))
            np.testing.assert_allclose(got, np.asarray(x @ A), **TOL)
            # the dropped connection surfaced as a death and its rows
            # were re-homed (shard re-shipped to the heir) -- the next
            # round decoded correctly without worker 2
            assert sum(r.deaths for r in cl.reports) == 1
            assert 2 not in cl.last_report.completed_per_worker

    @pytest.mark.slow
    def test_tcp_wrong_version_handshake_rejected(self, sparse_operand):
        A, x = sparse_operand
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        with plan.to_cluster(transport="tcp") as cl:
            # a client speaking a future wire version is rejected at
            # the handshake: connection closed, nothing registered
            blob = bytearray(encode_record({"record": "hello", "worker": 0}))
            blob[4] = WIRE_VERSION + 1          # bump the header version
            with socket.create_connection(
                    ("127.0.0.1", cl.transport.port), timeout=5) as sock:
                sock.sendall(struct.pack("<I", len(blob)) + bytes(blob))
                sock.settimeout(5)
                assert sock.recv(1) == b""      # server closed on us
            # ... and the cluster is unharmed
            np.testing.assert_allclose(np.asarray(cl.matvec(x)),
                                       np.asarray(x @ A), **TOL)

    @pytest.mark.slow
    def test_tcp_shutdown_releases_sockets_and_threads(self,
                                                       sparse_operand):
        import gc
        import warnings

        A, x = sparse_operand
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        with warnings.catch_warnings():
            warnings.simplefilter("error", ResourceWarning)
            with plan.to_cluster(transport="tcp") as cl:
                cl.matvec(x)
            gc.collect()                # unclosed sockets would warn here
        for t in threading.enumerate():
            assert not t.name.startswith(("cluster-tcp", "cluster-beat",
                                          "cluster-worker"))

    def test_memory_shutdown_joins_worker_threads(self, sparse_operand):
        A, x = sparse_operand
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        with plan.to_cluster() as cl:
            cl.matvec(x)
        time.sleep(0.05)
        leftover = [t.name for t in threading.enumerate()
                    if t.name.startswith(("cluster-worker", "cluster-beat"))]
        assert leftover == []

    def test_env_var_selects_transport(self, sparse_operand, monkeypatch):
        assert resolve_transport(None) == "memory"
        monkeypatch.setenv("REPRO_CLUSTER_TRANSPORT", "tcp")
        assert resolve_transport(None) == "tcp"
        assert resolve_transport("memory") == "memory"   # explicit wins
        with pytest.raises(ValueError, match="transport"):
            resolve_transport("carrier-pigeon")

    def test_bytes_on_wire_accounting(self, sparse_operand):
        # support-restricted task payloads: measured task traffic must
        # be well under full-operand shipping on a 98%-sparse operand,
        # and the totals must accumulate across rounds
        A, x = sparse_operand
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        with plan.to_cluster() as cl:
            assert cl.bytes_shards > 0
            cl.matvec(x)
            rep = cl.last_report
            assert 0 < rep.bytes_tasks < rep.bytes_tasks_dense
            assert rep.bytes_results > 0
            cl.matvec(x)
            totals = cl.wire_totals()
            assert totals["bytes_tasks_total"] == \
                rep.bytes_tasks + cl.last_report.bytes_tasks

    def test_shard_supports_cover_nonzero_tiles(self, sparse_operand):
        A, _ = sparse_operand
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        for shard in shard_plan(plan, 3):
            assert len(shard.supports) == len(shard.task_rows)
            kb = shard.t_pad // shard.bk
            for sup, task in zip(shard.supports, shard.tasks):
                assert sorted(sup) == sorted(set(task["indices"].tolist()))
                assert all(0 <= j < kb for j in sup)


# ---------------------------------------------------------------------------
# Shared-memory transport: zero-copy accounting + segment lifecycle
# ---------------------------------------------------------------------------


def _own_shm_segments():
    """Names of /dev/shm entries created by this process's transports."""
    try:
        entries = os.listdir("/dev/shm")
    except FileNotFoundError:           # non-Linux: lifecycle untestable
        pytest.skip("/dev/shm not available")
    return {e for e in entries if e.startswith(f"repro{os.getpid()}x")}


@pytest.mark.slow
class TestShmTransport:
    def test_zero_copy_task_path(self, sparse_operand):
        # the tentpole claim, at test scale: shm task frames carry
        # segment references, so coordinator-side task copies are the
        # header frames alone and the worker materializes no operand
        A, x = sparse_operand
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        with plan.to_cluster(transport="shm") as cl:
            cl.matvec(x)
            rep = cl.last_report
            # every byte copied on the task path is a header frame byte
            assert 0 < rep.bytes_copied <= rep.bytes_tasks
            assert rep.bytes_copied < rep.bytes_tasks_dense
            totals = cl.fleet.wire_totals()
            assert totals["bytes_copied_total"] == rep.bytes_copied
            # transport-level counter additionally holds the one-time
            # shard staging copies
            assert totals["transport_bytes_copied"] >= \
                rep.bytes_copied + totals["bytes_shards"]

    def test_segments_released_on_close(self, sparse_operand):
        A, x = sparse_operand
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        before = _own_shm_segments()
        with plan.to_cluster(transport="shm") as cl:
            assert _own_shm_segments() - before     # shard segments live
            for _ in range(3):
                cl.matvec(x)
        assert _own_shm_segments() == before        # all unlinked

    def test_remove_worker_drain_releases_shard_segments(self,
                                                         sparse_operand):
        from repro.cluster.fleet import CodedFleet

        A, x = sparse_operand
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        before = _own_shm_segments()
        with CodedFleet(6, transport="shm") as fleet:
            h = fleet.attach(plan)
            h.matvec(x)
            held = _own_shm_segments() - before
            assert held
            fleet.remove_worker(5, drain=True)
            # the leaver's shard segment was unlinked with it
            assert not any(key[0] == 5
                           for key in fleet.transport._shard_segs)
            np.testing.assert_allclose(np.asarray(h.matvec(x)),
                                       np.asarray(x @ A), **TOL)
        assert _own_shm_segments() == before

    def test_worker_crash_leaves_no_segments(self, sparse_operand):
        # SIGKILL mid-run: the coordinator owns every segment, so a
        # fail-stop child can leak nothing; recovery then close leaves
        # /dev/shm exactly as found
        A, x = sparse_operand
        plan = compile_plan(A, scheme="proposed", n=6, s=1,
                            backend="packed")
        before = _own_shm_segments()
        with plan.to_cluster(transport="shm") as cl:
            np.testing.assert_allclose(np.asarray(cl.matvec(x)),
                                       np.asarray(x @ A), **TOL)
            os.kill(cl.transport._procs[2].pid, signal.SIGKILL)
            time.sleep(0.3)
            np.testing.assert_allclose(np.asarray(cl.matvec(x)),
                                       np.asarray(x @ A), **TOL)
            assert sum(r.deaths for r in cl.reports) == 1
        assert _own_shm_segments() == before

    def test_garbled_and_wrong_version_frames_kill_worker(self,
                                                          sparse_operand):
        # a corrupt frame and a future-wire-version frame must both be
        # rejected with the codec's explicit error (the worker answers
        # with a death notice and the fleet re-homes its rows)
        A, x = sparse_operand
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        before = _own_shm_segments()
        with plan.to_cluster(transport="shm") as cl:
            cl.transport.garble(1)
            bad = bytearray(
                Task(round=999, op="matvec", task_row=0,
                     payload={}, meta={}).encode())
            bad[4] = WIRE_VERSION + 1
            cl.transport._send(2, ("task", bytes(bad)))
            deadline = time.time() + 10
            while sum(1 for w in (1, 2)
                      if not cl.transport.alive(w)) < 2 \
                    and time.time() < deadline:
                time.sleep(0.02)
            assert not cl.transport.alive(1)
            assert not cl.transport.alive(2)
            np.testing.assert_allclose(np.asarray(cl.matvec(x)),
                                       np.asarray(x @ A), **TOL)
        assert _own_shm_segments() == before


# ---------------------------------------------------------------------------
# Fault injectors
# ---------------------------------------------------------------------------


class TestFaults:
    def test_straggler_mask_matches_model(self):
        model = AdversarialSlow(stragglers=(1, 4), slowdown=50.0)
        done = straggler_mask(6, 2, np.random.default_rng(0), model)
        assert not done[1] and not done[4] and done.sum() == 4

    def test_per_worker_streams_deterministic(self):
        a = StragglerFaults(time_scale=1.0, seed=3)
        b = StragglerFaults(time_scale=1.0, seed=3)
        da = [a.delay(w, 0, 0.5) for w in (0, 1, 0, 2)]
        db = [b.delay(w, 0, 0.5) for w in (0, 1, 0, 2)]
        assert da == db
        assert all(d > 0 for d in da)

    def test_spec_roundtrip(self):
        for inj in (NoFaults(),
                    StragglerFaults(time_scale=2e-3, seed=5),
                    adversarial_faults([2], slowdown=7.0),
                    FailStop({1: 2}, base=StragglerFaults(seed=9)),
                    Hang({0: 1}, base=StragglerFaults(seed=4))):
            back = from_spec(inj.to_spec())
            assert type(back) is type(inj)
            assert back.to_spec() == inj.to_spec()
        assert isinstance(from_spec(None), NoFaults)
        with pytest.raises(ValueError, match="unknown fault spec"):
            from_spec({"kind": "nope"})

    def test_failstop_predicate(self):
        f = FailStop({0: 2})
        assert not f.should_fail(0, 1)
        assert f.should_fail(0, 2)
        assert not f.should_fail(1, 99)
        assert not f.mask(4, 1)[0]

    def test_hang_predicate(self):
        h = Hang({1: 1})
        assert not h.should_hang(1, 0)
        assert h.should_hang(1, 1)
        assert not h.should_hang(0, 99)
        assert not h.should_fail(1, 99)     # silent, never fail-stop
        assert not h.mask(4, 1)[1]


# ---------------------------------------------------------------------------
# Serve-engine routing + online re-tuning
# ---------------------------------------------------------------------------


class TestSurfaceIntegration:
    def test_engine_mask_routes_through_faults(self):
        from repro.configs import get_smoke_config
        from repro.configs.base import CodedConfig
        from repro.models import build_model
        from repro.serve import ServeEngine

        import jax

        cfg = get_smoke_config("qwen3-14b")
        model = build_model(cfg, dtype=jnp.float32)
        params = model.init(jax.random.key(0))
        eng = ServeEngine(
            model, params, cfg, batch_size=2, max_len=32,
            coded=CodedConfig(enabled=True, n_workers=6, stragglers=2,
                              cluster=True, cluster_workers=3),
            faults=StragglerFaults(
                model=AdversarialSlow(stragglers=(0, 1), slowdown=50.0)))
        mask = np.asarray(eng._straggler_mask())
        assert not mask[0] and not mask[1]      # the injected model decides
        hidden = jnp.asarray(np.random.default_rng(0).standard_normal(
            (2, cfg.d_model)), jnp.float32)
        head = params["embed"].T if cfg.tie_embeddings else params["head"]
        out = eng.coded_logits(hidden)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(hidden @ head), **TOL)
        assert eng.coded_cluster.last_report is not None
        eng.close()
        assert eng.coded_cluster is None

    def test_retune_follows_density(self):
        rng = np.random.default_rng(9)
        t, r = 256, 144
        A_sparse = jnp.asarray(block_sparse(rng, t, r, 0.99))
        A_dense = jnp.asarray(rng.standard_normal((t, r)), jnp.float32)
        x = jnp.asarray(rng.standard_normal(t), jnp.float32)
        plan = compile_plan(A_sparse, scheme="proposed", n=6, s=2)
        assert plan.backend == "packed"
        assert plan.retune() == "packed"              # no drift: no-op
        assert plan.retune(A_dense) == "reference"    # crossed down
        np.testing.assert_allclose(np.asarray(plan.matvec(x)),
                                   np.asarray(x @ A_dense), **TOL)
        assert plan.retune(A_sparse) == "packed"      # crossed back up
        np.testing.assert_allclose(np.asarray(plan.matvec(x)),
                                   np.asarray(x @ A_sparse), **TOL)
        agg = compile_plan(scheme="proposed", n=6, s=2)
        with pytest.raises(ValueError, match="no operand"):
            agg.retune()

    def test_reship_after_retune(self, sparse_operand):
        # plan.retune recompiles the packed shards; the cluster's
        # workers then hold stale BSR tables until reship() re-ships
        rng = np.random.default_rng(11)
        t, r = 256, 144
        A_sparse, x = sparse_operand
        A_dense = jnp.asarray(rng.standard_normal((t, r)), jnp.float32)
        plan = compile_plan(A_sparse, scheme="proposed", n=6, s=2)
        with plan.to_cluster() as cl:
            np.testing.assert_allclose(np.asarray(cl.matvec(x)),
                                       np.asarray(x @ A_sparse), **TOL)
            shards_before = cl.bytes_shards
            assert plan.retune(A_dense) == "reference"
            sent = cl.reship()
            assert sent > 0
            assert cl.bytes_shards == shards_before + sent
            np.testing.assert_allclose(np.asarray(cl.matvec(x)),
                                       np.asarray(x @ A_dense), **TOL)

    def test_list_schemes_cli(self, capsys):
        from repro.api.__main__ import format_scheme_table, main

        table = format_scheme_table()
        assert "proposed" in table and "weight law" in table
        assert format_scheme_table("mm").count("\n") < table.count("\n")
        assert main(["--list-schemes"]) == 0
        out = capsys.readouterr().out
        assert "repetition" in out and "NO" in out   # resilience column

    def test_trainer_retunes_every_n_steps(self, tmp_path):
        from repro.configs import get_smoke_config
        from repro.data.pipeline import DataConfig, make_pipeline
        from repro.models import build_model
        from repro.optim.adamw import AdamWConfig
        from repro.train import TrainConfig, Trainer

        rng = np.random.default_rng(10)
        A = jnp.asarray(block_sparse(rng, 128, 96, 0.99))
        plan = compile_plan(A, scheme="proposed", n=6, s=2)
        cfg = get_smoke_config("phi3-mini-3.8b")
        model = build_model(cfg, dtype=jnp.float32)
        dcfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)
        tr = Trainer(model, AdamWConfig(lr=1e-3, warmup_steps=2,
                                        total_steps=4),
                     TrainConfig(steps=4, ckpt_dir=None, retune_every=2),
                     coded_plans=[(plan, lambda params: A)])
        tr.fit(lambda s: make_pipeline(dcfg, s), resume=False)
        assert [r["step"] for r in tr.retunes] == [1, 3]
        assert all(r["backend"] == "packed" for r in tr.retunes)

    def test_trainer_reships_cluster_after_retune(self):
        from repro.configs import get_smoke_config
        from repro.data.pipeline import DataConfig, make_pipeline
        from repro.models import build_model
        from repro.optim.adamw import AdamWConfig
        from repro.train import TrainConfig, Trainer

        rng = np.random.default_rng(12)
        A_sparse = jnp.asarray(block_sparse(rng, 128, 96, 0.99))
        A_dense = jnp.asarray(rng.standard_normal((128, 96)), jnp.float32)
        plan = compile_plan(A_sparse, scheme="proposed", n=6, s=2)
        cfg = get_smoke_config("phi3-mini-3.8b")
        model = build_model(cfg, dtype=jnp.float32)
        dcfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)
        with plan.to_cluster() as cl:
            # the provider drifts the operand across the crossover:
            # the first retune recompiles and must re-ship the shards
            tr = Trainer(model, AdamWConfig(lr=1e-3, warmup_steps=2,
                                            total_steps=4),
                         TrainConfig(steps=4, ckpt_dir=None, retune_every=2),
                         coded_plans=[(plan, lambda params: A_dense, cl)])
            tr.fit(lambda s: make_pipeline(dcfg, s), resume=False)
            assert tr.retunes[0]["backend"] == "reference"
            assert tr.retunes[0]["reshipped_bytes"] > 0
            # second retune: same operand object, nothing recompiled
            assert "reshipped_bytes" not in tr.retunes[1]
            x = jnp.asarray(rng.standard_normal(128), jnp.float32)
            np.testing.assert_allclose(np.asarray(cl.matvec(x)),
                                       np.asarray(x @ A_dense), **TOL)
