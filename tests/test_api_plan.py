"""Scheme registry + plan API suite.

Covers: registry metadata and error paths, the full decode sweep
(every registered resilient scheme, every (n choose s) straggler
pattern), the density-based automatic backend pick (the
BENCH_runtime.json crossover), plan matvec/matmat/aggregate parity
against the reference backend, the aggregation cache, and the
deprecation shims on the old constructor dicts.
"""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    CodedPlan,
    DEFAULT_DENSITY_CROSSOVER,
    SchemeInfo,
    block_zero_fraction,
    choose_backend,
    compile_plan,
    density_crossover,
    list_schemes,
    make_scheme,
    register_scheme,
    scheme_info,
    scheme_names,
)
from repro.core.assignment import MVScheme

TOL = dict(rtol=5e-3, atol=5e-3)


def block_sparse(rng, t, r, zeros, bs=8):
    """Matrix with whole (bs x bs) tiles zeroed with probability ``zeros``."""
    mask = rng.random((t // bs, r // bs)) >= zeros
    a = rng.standard_normal((t, r)).astype(np.float32)
    return a * np.kron(mask, np.ones((bs, bs), np.float32))


def all_straggler_masks(n, s):
    for pat in itertools.combinations(range(n), s):
        done = np.ones(n, bool)
        done[list(pat)] = False
        yield jnp.asarray(done)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_metadata_complete(self):
        infos = list_schemes()
        assert {("mv", "proposed"), ("mv", "cyclic31"), ("mv", "scs36"),
                ("mm", "proposed"), ("mm", "poly")} <= {
                    (i.kind, i.name) for i in infos}
        for i in infos:
            assert isinstance(i, SchemeInfo)
            assert i.weight and i.regime       # metadata, not placeholders
        # kinds filter + names helper
        assert all(i.kind == "mm" for i in list_schemes("mm"))
        assert "proposed-hetero" in scheme_names("mv")
        assert scheme_info("repetition").straggler_resilient is False
        assert scheme_info("proposed").sparse is True
        assert scheme_info("poly").sparse is False

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            register_scheme("proposed", "mv")(lambda n, k_A: None)

    def test_register_new_scheme_roundtrip(self):
        @register_scheme("test-identity", "mv", sparse=True, weight="1",
                         regime="test", straggler_resilient=False)
        def ident(n, k_A):
            from repro.core.assignment import repetition_mv
            return repetition_mv(n, k_A)

        try:
            sch = make_scheme("test-identity", n=4, k_A=4)
            assert isinstance(sch, MVScheme)
        finally:
            # keep the global registry clean for other tests
            from repro.api.schemes import _REGISTRY
            del _REGISTRY[("mv", "test-identity")]

    def test_make_scheme_error_paths(self):
        with pytest.raises(KeyError, match="unknown mv scheme"):
            make_scheme("nope", n=6, k_A=4)
        with pytest.raises(ValueError, match="n="):
            make_scheme("proposed", k_A=4)
        with pytest.raises(ValueError, match="k_A= or s="):
            make_scheme("proposed", n=6)
        with pytest.raises(ValueError, match="inconsistent"):
            make_scheme("proposed", n=6, k_A=4, s=3)
        with pytest.raises(ValueError, match="both k_A= and k_B="):
            make_scheme("proposed", n=6, k_A=2, kind="mm")
        with pytest.raises(ValueError, match="capacities"):
            make_scheme("proposed-hetero", k_A=3)
        with pytest.raises(ValueError, match="hetero"):
            make_scheme("proposed", n=6, k_A=4, capacities=[2, 1, 1])
        with pytest.raises(ValueError, match="kind"):
            list_schemes("nope")

    def test_s_alias_and_consistency(self):
        assert make_scheme("proposed", n=6, s=2).k_A == 4
        with pytest.raises(ValueError, match="inconsistent s"):
            make_scheme("proposed", n=6, k_A=2, k_B=2, s=3)


# ---------------------------------------------------------------------------
# Full decode sweep: every resilient scheme, every straggler pattern
# ---------------------------------------------------------------------------


class TestDecodeSweep:
    @pytest.mark.parametrize("name", [
        i.name for i in list_schemes("mv")
        if i.straggler_resilient and not i.hetero])
    def test_mv_all_patterns(self, name):
        n, k = 6, 4
        rng = np.random.default_rng(hash(name) % 2**31)
        A = jnp.asarray(rng.standard_normal((24, 16)), jnp.float32)
        x = jnp.asarray(rng.standard_normal((24,)), jnp.float32)
        expect = np.asarray(x @ A)
        plan = compile_plan(A, scheme=name, n=n, k_A=k, backend="reference")
        for done in all_straggler_masks(n, n - k):
            np.testing.assert_allclose(
                np.asarray(plan.matvec(x, done)), expect, **TOL)

    def test_mv_hetero_all_patterns(self):
        caps, k = [2, 1, 1, 1], 3           # n = 5 virtual workers, s = 2
        rng = np.random.default_rng(5)
        A = jnp.asarray(rng.standard_normal((18, 12)), jnp.float32)
        x = jnp.asarray(rng.standard_normal((18,)), jnp.float32)
        plan = compile_plan(A, scheme="proposed-hetero", capacities=caps,
                            k_A=k, backend="reference")
        assert plan.n == sum(caps)
        for done in all_straggler_masks(plan.n, plan.s):
            np.testing.assert_allclose(
                np.asarray(plan.matvec(x, done)), np.asarray(x @ A), **TOL)

    @pytest.mark.parametrize("name", [i.name for i in list_schemes("mm")])
    def test_mm_all_patterns(self, name):
        n, ka, kb = 6, 2, 2                 # s = 2, 15 patterns
        rng = np.random.default_rng(hash(name) % 2**31)
        A = jnp.asarray(rng.standard_normal((24, 10)), jnp.float32)
        B = jnp.asarray(rng.standard_normal((24, 8)), jnp.float32)
        expect = np.asarray(A.T @ B)
        plan = compile_plan(A, scheme=name, n=n, k_A=ka, k_B=kb,
                            backend="reference")
        for done in all_straggler_masks(n, n - ka * kb):
            np.testing.assert_allclose(
                np.asarray(plan.matmat(B, done)), expect, **TOL)

    def test_repetition_flagged_not_resilient_but_compiles(self):
        rng = np.random.default_rng(6)
        A = jnp.asarray(rng.standard_normal((16, 12)), jnp.float32)
        x = jnp.asarray(rng.standard_normal((16,)), jnp.float32)
        plan = compile_plan(A, scheme="repetition", n=6, k_A=4,
                            backend="reference")
        np.testing.assert_allclose(np.asarray(plan.matvec(x)),
                                   np.asarray(x @ A), **TOL)

    def test_compile_plan_auto_for_every_registered_name(self):
        """Acceptance: compile_plan(A, scheme=s, backend="auto") works
        for every name in list_schemes()."""
        rng = np.random.default_rng(7)
        A = jnp.asarray(rng.standard_normal((24, 16)), jnp.float32)
        for info in list_schemes():
            kw = {}
            if info.hetero:
                kw["capacities"] = [2, 1, 1, 1]
                kw["k_A"] = 3
            elif info.kind == "mm":
                kw.update(n=6, k_A=2, k_B=2)
            else:
                kw.update(n=6, k_A=4)
            plan = compile_plan(A, scheme=info.name, backend="auto", **kw)
            assert plan.backend in ("reference", "packed", "pallas",
                                    "pallas-interpret")
            assert plan.describe()["scheme"] == info.name


# ---------------------------------------------------------------------------
# Automatic backend choice
# ---------------------------------------------------------------------------


class TestAutoBackend:
    def test_block_zero_fraction(self):
        a = np.zeros((32, 32), np.float32)
        a[:8, :8] = 1.0
        assert block_zero_fraction(a) == pytest.approx(15 / 16)
        assert block_zero_fraction(np.ones((16, 16))) == 0.0

    @staticmethod
    def _pin_crossover(monkeypatch, value=DEFAULT_DENSITY_CROSSOVER):
        # the process-wide crossover may have been derived from a local
        # BENCH_runtime.json; pin it so the decision is deterministic
        import repro.api.backends as backends_mod
        monkeypatch.setattr(backends_mod, "_measured_crossover", value)

    def test_auto_picks_packed_above_crossover(self, monkeypatch):
        monkeypatch.delenv("REPRO_CODED_BACKEND", raising=False)
        self._pin_crossover(monkeypatch)
        rng = np.random.default_rng(8)
        sparse = block_sparse(rng, 128, 64, zeros=0.99)
        assert block_zero_fraction(sparse) >= DEFAULT_DENSITY_CROSSOVER
        plan = compile_plan(jnp.asarray(sparse), scheme="proposed",
                            n=6, k_A=4, backend="auto")
        assert plan.backend == "packed"

    def test_auto_picks_reference_below_crossover(self, monkeypatch):
        monkeypatch.delenv("REPRO_CODED_BACKEND", raising=False)
        self._pin_crossover(monkeypatch)
        rng = np.random.default_rng(9)
        dense = rng.standard_normal((128, 64)).astype(np.float32)
        plan = compile_plan(jnp.asarray(dense), scheme="proposed",
                            n=6, k_A=4, backend="auto")
        assert plan.backend == "reference"
        # mid-density: below the 0.97 crossover stays reference too
        mid = block_sparse(rng, 128, 64, zeros=0.5)
        assert choose_backend(mid, "auto") == "reference"

    def test_env_override_beats_auto(self, monkeypatch):
        self._pin_crossover(monkeypatch)
        rng = np.random.default_rng(10)
        sparse = block_sparse(rng, 64, 32, zeros=0.995)
        monkeypatch.setenv("REPRO_CODED_BACKEND", "reference")
        assert choose_backend(sparse, "auto") == "reference"
        plan = compile_plan(jnp.asarray(sparse), scheme="proposed",
                            n=6, k_A=4, backend="auto")
        assert plan.backend == "reference"
        # env=auto re-enables the density pick (documented contract)
        monkeypatch.setenv("REPRO_CODED_BACKEND", "auto")
        assert choose_backend(sparse, "packed") == "packed"
        assert choose_backend(sparse, "auto") == "packed"
        dense = np.ones((64, 32), np.float32)
        assert choose_backend(dense, "auto") == "reference"

    def test_explicit_backend_still_wins_without_env(self, monkeypatch):
        monkeypatch.delenv("REPRO_CODED_BACKEND", raising=False)
        dense = np.ones((64, 32), np.float32)
        assert choose_backend(dense, "packed") == "packed"
        with pytest.raises(ValueError, match="unknown coded backend"):
            choose_backend(dense, "nope")

    def test_auto_applies_bench_derived_crossover(self, monkeypatch, tmp_path):
        """Regenerating BENCH_runtime.json moves the auto decision."""
        import repro.api.backends as backends_mod
        payload = {"results": [
            {"zeros": 0.5, "backend": "packed", "speedup_vs_reference": 1.5},
        ]}
        p = tmp_path / "bench.json"
        p.write_text(__import__("json").dumps(payload))
        monkeypatch.setenv("REPRO_BENCH_RUNTIME", str(p))
        monkeypatch.setattr(backends_mod, "_measured_crossover", None)
        monkeypatch.delenv("REPRO_CODED_BACKEND", raising=False)
        assert backends_mod._auto_crossover() == pytest.approx(0.5)
        rng = np.random.default_rng(22)
        mid = block_sparse(rng, 128, 64, zeros=0.7)   # above the new 0.5
        assert choose_backend(mid, "auto") == "packed"

    def test_density_crossover_from_bench_json(self, tmp_path):
        payload = {"results": [
            {"zeros": 0.95, "backend": "packed", "speedup_vs_reference": 0.6},
            {"zeros": 0.98, "backend": "packed", "speedup_vs_reference": 1.4},
            {"zeros": 0.99, "backend": "packed", "speedup_vs_reference": 3.2},
        ]}
        p = tmp_path / "BENCH_runtime.json"
        p.write_text(__import__("json").dumps(payload))
        assert density_crossover(str(p)) == pytest.approx(0.965)
        assert density_crossover(None) == DEFAULT_DENSITY_CROSSOVER
        assert density_crossover(str(tmp_path / "missing.json")) == \
            DEFAULT_DENSITY_CROSSOVER


# ---------------------------------------------------------------------------
# Plan operations: backend parity, caching, error paths
# ---------------------------------------------------------------------------


class TestPlanOps:
    @pytest.mark.parametrize("backend", ["packed", "pallas-interpret"])
    def test_matvec_parity_random_masks(self, backend):
        rng = np.random.default_rng(11)
        A = jnp.asarray(block_sparse(rng, 64, 48, zeros=0.9), jnp.float32)
        x = jnp.asarray(rng.standard_normal((3, 64)), jnp.float32)
        ref = compile_plan(A, scheme="proposed", n=6, k_A=4,
                           backend="reference")
        plan = compile_plan(A, scheme="proposed", n=6, k_A=4,
                            backend=backend)
        for _ in range(4):
            done = np.ones(6, bool)
            done[rng.choice(6, 2, replace=False)] = False
            np.testing.assert_allclose(
                np.asarray(plan.matvec(x, jnp.asarray(done))),
                np.asarray(ref.matvec(x, jnp.asarray(done))),
                rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("backend", ["packed", "pallas-interpret"])
    def test_matmat_parity_random_masks(self, backend):
        rng = np.random.default_rng(12)
        A = jnp.asarray(rng.standard_normal((32, 24)), jnp.float32)
        B = jnp.asarray(rng.standard_normal((32, 18)), jnp.float32)
        ref = compile_plan(A, scheme="proposed", n=12, k_A=3, k_B=3,
                           backend="reference")
        plan = compile_plan(A, scheme="proposed", n=12, k_A=3, k_B=3,
                            backend=backend)
        for _ in range(3):
            done = np.ones(12, bool)
            done[rng.choice(12, 3, replace=False)] = False
            np.testing.assert_allclose(
                np.asarray(plan.matmat(B, jnp.asarray(done))),
                np.asarray(ref.matmat(B, jnp.asarray(done))),
                rtol=2e-4, atol=2e-4)

    def test_prewarm_and_cache_reuse(self):
        rng = np.random.default_rng(13)
        A = jnp.asarray(block_sparse(rng, 64, 48, zeros=0.99), jnp.float32)
        plan = compile_plan(A, scheme="proposed", n=6, k_A=4,
                            backend="packed")
        cache = plan.executor.cache
        assert (cache.hits, cache.misses) == (0, 1)    # all-alive prewarmed
        x = jnp.asarray(rng.standard_normal((64,)), jnp.float32)
        plan.matvec(x)                                  # all-alive -> hit
        assert (cache.hits, cache.misses) == (1, 1)
        done = jnp.asarray([True, False, True, True, False, True])
        plan.matvec(x, done)
        plan.matvec(x, done)
        assert (cache.hits, cache.misses) == (2, 2)

    def test_aggregate_matches_sum_and_caches(self):
        n, s = 6, 2
        rng = np.random.default_rng(14)
        plan = compile_plan(scheme="proposed", n=n, s=s)   # aggregation-only
        k = plan.k
        R = plan.G
        grads = [{"w": jnp.asarray(rng.standard_normal((3, 4)), jnp.float32)}
                 for _ in range(k)]
        payloads = []
        for i in range(n):
            acc = None
            for q in plan.scheme.supports[i]:
                term = jax.tree.map(lambda g: float(R[i, q]) * g, grads[q])
                acc = term if acc is None else jax.tree.map(jnp.add, acc, term)
            payloads.append(acc)
        expect = jax.tree.map(lambda *xs: sum(xs), *grads)
        for done in all_straggler_masks(n, s):
            out = plan.aggregate(payloads, done)
            np.testing.assert_allclose(np.asarray(out["w"]),
                                       np.asarray(expect["w"]), **TOL)
        cache = plan._decode_cache()
        first = (cache.hits, cache.misses)
        plan.aggregate(payloads, jnp.asarray(
            [False, False, True, True, True, True]))
        assert (cache.hits, cache.misses) == (first[0] + 1, first[1])

    def test_wrong_kind_and_missing_operand_raise(self):
        rng = np.random.default_rng(15)
        A = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
        mv = compile_plan(A, scheme="proposed", n=6, k_A=4,
                          backend="reference")
        mm = compile_plan(A, scheme="proposed", n=6, k_A=2, k_B=2,
                          backend="reference")
        agg = compile_plan(scheme="proposed", n=6, s=2)
        with pytest.raises(ValueError, match="mm plan"):
            mv.matmat(A)
        with pytest.raises(ValueError, match="mv plan"):
            mm.matvec(A[0])
        with pytest.raises(ValueError, match="mv plan"):
            mm.aggregate([])
        with pytest.raises(ValueError, match="without an operand"):
            agg.matvec(A[0])
        with pytest.raises(ValueError, match="mm plan"):
            agg.matmat(A)          # kind check fires first (mv plan)
        with pytest.raises(ValueError, match="holds no shards"):
            agg.worker_tile_counts()
        with pytest.raises(ValueError, match="2-D"):
            compile_plan(jnp.ones((2, 3, 4)), scheme="proposed", n=6, k_A=4)

    def test_delta_partition_scheme_worker_mask_expansion(self):
        """scs36 runs tasks_per_worker tasks per worker; the plan
        expands a worker-level done mask to task rows."""
        rng = np.random.default_rng(16)
        n, k = 6, 4                       # Delta = 12, per = 3
        A = jnp.asarray(rng.standard_normal((24, 16)), jnp.float32)
        x = jnp.asarray(rng.standard_normal((24,)), jnp.float32)
        plan = compile_plan(A, scheme="scs36", n=n, k_A=k,
                            backend="reference")
        assert plan.tasks_per_worker == 3
        assert plan.n_tasks == n * 3
        done = jnp.asarray([True, False, True, True, False, True])
        np.testing.assert_allclose(np.asarray(plan.matvec(x, done)),
                                   np.asarray(x @ A), **TOL)

    def test_plan_under_jit_falls_back_to_reference(self):
        rng = np.random.default_rng(17)
        A = jnp.asarray(rng.standard_normal((32, 24)), jnp.float32)
        x = jnp.asarray(rng.standard_normal((32,)), jnp.float32)

        def f(a, v):
            return compile_plan(a, scheme="proposed", n=6, k_A=4,
                                backend="packed").matvec(v)

        out = jax.jit(f)(A, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x @ A),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Operator shims route through plans
# ---------------------------------------------------------------------------


class TestShims:
    def test_scheme_dicts_removed_registry_covers(self):
        # the PR-2 deprecation shims are gone; the registry is the only
        # lookup surface and it covers everything the dicts offered
        import repro.core.assignment as assignment

        assert not hasattr(assignment, "MV_SCHEMES")
        assert not hasattr(assignment, "MM_SCHEMES")
        assert {"proposed", "poly", "orthopoly", "rkrp", "cyclic31",
                "scs36", "class29", "repetition"} <= set(scheme_names("mv"))
        assert {"proposed", "poly", "orthopoly", "rkrp",
                "cyclic31"} <= set(scheme_names("mm"))
        sch = make_scheme("poly", n=12, k_A=9)
        assert sch.name == "poly" and sch.omega_A == 9

    def test_coded_operator_exposes_its_plan(self):
        from repro.core import CodedOperator, proposed_mv

        rng = np.random.default_rng(18)
        A = jnp.asarray(rng.standard_normal((32, 24)), jnp.float32)
        op = CodedOperator.build(A, proposed_mv(6, 4), seed=1,
                                 backend="packed")
        plan = op.plan()
        assert isinstance(plan, CodedPlan)
        assert plan.executor is op.executor()          # shared cache
        x = jnp.asarray(rng.standard_normal((32,)), jnp.float32)
        done = jnp.asarray([True, False, True, True, False, True])
        np.testing.assert_allclose(np.asarray(op.apply(x, done)),
                                   np.asarray(plan.matvec(x, done)),
                                   rtol=0, atol=0)

    def test_coded_linear_exposes_its_plan(self):
        from repro.parallel.coded_layer import CodedLinear

        rng = np.random.default_rng(19)
        w = jnp.asarray(rng.standard_normal((16, 24)), jnp.float32)
        layer = CodedLinear.build(w, 6, 2, seed=0, backend="packed")
        assert layer.plan().executor is layer.executor()
        assert layer.plan().backend == "packed"

    def test_coded_linear_delta_partition_scheme(self):
        """CodedLinear admits Delta-partition schemes: worker-level done
        masks expand to task rows through the plan (eager and jit)."""
        from repro.parallel.coded_layer import CodedLinear

        rng = np.random.default_rng(23)
        w = jnp.asarray(rng.standard_normal((16, 24)), jnp.float32)
        layer = CodedLinear.build(w, 6, 2, seed=0, scheme="scs36")
        assert layer.scheme.tasks_per_worker == 3       # Delta = 12
        x = jnp.asarray(rng.standard_normal((3, 16)), jnp.float32)
        done = jnp.asarray([True, False, True, True, False, True])
        np.testing.assert_allclose(np.asarray(layer.apply(x, done)),
                                   np.asarray(x @ w), **TOL)
        jit_out = jax.jit(layer.apply)(x, done)
        np.testing.assert_allclose(np.asarray(jit_out), np.asarray(x @ w),
                                   **TOL)

    def test_coded_operator_delta_partition_under_jit(self):
        from repro.core import CodedOperator
        from repro.core.assignment import scs_mv

        rng = np.random.default_rng(24)
        A = jnp.asarray(rng.standard_normal((24, 16)), jnp.float32)
        x = jnp.asarray(rng.standard_normal((24,)), jnp.float32)
        done = jnp.asarray([True, False, True, True, False, True])
        sch = scs_mv(6, 4)
        out = jax.jit(
            lambda a, v, d: CodedOperator.build(a, sch).apply(v, d))(
                A, x, done)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x @ A), **TOL)

    def test_resilient_only_scheme_names_for_clis(self):
        names = scheme_names("mv", resilient_only=True)
        assert "repetition" not in names          # undecodable patterns
        assert "proposed-hetero" not in names     # needs capacities
        assert "proposed" in names and "cyclic31" in names

    def test_coded_aggregator_lru_reuse(self):
        """ROADMAP item: repeated steps under the same done mask reuse
        the cached inverse instead of re-solving a k x k system."""
        from repro.parallel.coded_grads import CodedAggregator

        rng = np.random.default_rng(20)
        agg = CodedAggregator.build(6, 2, seed=1)
        grads = [{"w": jnp.asarray(rng.standard_normal((3,)), jnp.float32)}
                 for _ in range(4)]
        payloads = [agg.worker_payload(i, grads) for i in range(6)]
        done = jnp.asarray([True, False, True, True, False, True])

        inv_calls = {"n": 0}
        real_inv = np.linalg.inv

        def counting_inv(a):
            inv_calls["n"] += 1
            return real_inv(a)

        expect = jax.tree.map(lambda *xs: sum(xs), *grads)
        import unittest.mock as mock
        with mock.patch.object(np.linalg, "inv", counting_inv):
            for _ in range(5):
                out = agg.aggregate(payloads, done)
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(expect["w"]), **TOL)
        assert inv_calls["n"] == 1                     # one solve, 4 hits

    def test_coded_moe_parity_under_stragglers(self):
        """ROADMAP item: MoE expert matmuls through the plan API."""
        from repro.configs.base import MoEConfig
        from repro.models.moe import CodedMoE, init_moe_params, moe_block

        moe = MoEConfig(n_experts=4, top_k=2, d_expert=32)
        p = init_moe_params(jax.random.key(0), 16, moe)
        x = jnp.asarray(np.random.default_rng(21).standard_normal((2, 8, 16)),
                        jnp.float32)
        ref, aux_ref = moe_block(p, x, moe)
        cm = CodedMoE(p, moe, n_workers=6, stragglers=2, backend="auto")
        assert set(cm.backends()) <= {"reference", "packed"}
        for done in (None,
                     jnp.asarray([True, False, True, True, False, True]),
                     jnp.asarray([False, True, True, False, True, True])):
            out, aux = cm(x, done)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-6)
