"""Tests for Alg. 1 / Alg. 2 assignment structure (Lemmas 1-2, Hall condition)."""

import itertools

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import make_scheme
from repro.core import (
    appearances,
    alg1_supports,
    cyclic31_mm,
    make_hetero_system,
    mm_unknown_supports,
    proposed_mm,
    proposed_mv,
    scs_mv,
    union_cover_count,
)
from repro.core.weights import choose_mm_weights


def mv_cases():
    return [(6, 4), (12, 9), (10, 7), (20, 16), (30, 21), (9, 6), (8, 4)]


class TestAlg1Structure:
    def test_example1_fig1(self):
        assert alg1_supports(6, 4) == [
            (0, 1), (1, 2), (2, 3), (3, 0), (0, 1), (2, 3)]

    def test_example3_fig2(self):
        sup = alg1_supports(12, 9)
        assert sup[:9] == [tuple((i + j) % 9 for j in range(3)) for i in range(9)]
        assert sup[9:] == [(0, 1, 2), (3, 4, 5), (6, 7, 8)]

    def test_weight_is_homogeneous_and_minimal(self):
        for n, k in mv_cases():
            sch = proposed_mv(n, k)
            assert all(len(t) == sch.omega_A for t in sch.supports)

    def test_appearance_count(self):
        """Prop. 1 proof ingredient: every unknown appears in >= s+1 workers."""
        for n, k in mv_cases():
            sch = proposed_mv(n, k)
            cnt = appearances(sch.supports, k)
            assert cnt.min() >= sch.s + 1, (n, k, cnt)

    def test_lemma1_hall_condition_exhaustive_small(self):
        """Lemma 1: any m <= k_A workers cover >= m unknowns (exhaustive)."""
        for n, k in [(6, 4), (9, 6), (10, 7), (8, 4)]:
            sch = proposed_mv(n, k)
            for m in range(1, k + 1):
                for combo in itertools.combinations(range(n), m):
                    assert union_cover_count(sch.supports, list(combo)) >= m

    @given(st.integers(2, 40), st.data())
    @settings(max_examples=120, deadline=None)
    def test_lemma1_hall_condition_sampled(self, s, data):
        k = data.draw(st.integers(s, min(3 * s * s + 3, 60)))
        n = k + s
        sch = proposed_mv(n, k)
        rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
        m = int(data.draw(st.integers(1, k)))
        combo = rng.choice(n, size=m, replace=False).tolist()
        assert union_cover_count(sch.supports, combo) >= m


class TestAlg2Structure:
    def test_fig4_allocation(self):
        sch = proposed_mm(20, 4, 4)
        assert sch.omega_A == sch.omega_B == 2
        # W_0 group: A-support cyclic, B-support per j=floor(i/k_A)
        assert sch.supports_A[0] == (0, 1) and sch.supports_B[0] == (0, 1)
        assert sch.supports_A[5] == (1, 2) and sch.supports_B[5] == (1, 2)
        # extra workers 16..19 (checked against Alg. 2 lines 9-11)
        assert sch.supports_A[16] == (0, 1) and sch.supports_B[16] == (0, 1)
        assert sch.supports_A[17] == (2, 3) and sch.supports_B[17] == (0, 1)
        assert sch.supports_A[18] == (0, 1) and sch.supports_B[18] == (2, 3)
        assert sch.supports_A[19] == (2, 3) and sch.supports_B[19] == (2, 3)

    def test_class_structure(self):
        """Sec. V-1: within class M_i (i mod k_A), A-supports identical."""
        sch = proposed_mm(42, 6, 6)
        k = 36
        for i in range(sch.k_A):
            cls = [w for w in range(k) if w % sch.k_A == i]
            sups = {sch.supports_A[w] for w in cls}
            assert len(sups) == 1

    def test_mm_appearance_count(self):
        for n, ka, kb in [(20, 4, 4), (42, 6, 6), (38, 6, 6), (18, 4, 4)]:
            sch = proposed_mm(n, ka, kb)
            unk = mm_unknown_supports(sch)
            cnt = appearances(unk, ka * kb)
            assert cnt.min() >= sch.s + 1, (n, ka, kb, int(cnt.min()))

    def test_lemma2_hall_condition_sampled(self):
        rng = np.random.default_rng(0)
        for n, ka, kb in [(20, 4, 4), (42, 6, 6), (40, 6, 6)]:
            sch = proposed_mm(n, ka, kb)
            unk = mm_unknown_supports(sch)
            k = ka * kb
            for _ in range(300):
                m = int(rng.integers(1, k + 1))
                combo = rng.choice(n, size=m, replace=False).tolist()
                assert union_cover_count(unk, combo) >= m

    def test_weight_homogeneous(self):
        sch = proposed_mm(42, 6, 6)
        assert all(len(a) == 2 and len(b) == 3
                   for a, b in zip(sch.supports_A, sch.supports_B))
        assert sch.weight() == 6

    def test_cyclic31_weight_higher(self):
        ours = proposed_mm(42, 6, 6).weight()
        theirs = cyclic31_mm(42, 6, 6).weight()
        assert theirs == 8 and ours == 6


class TestBaselines:
    def test_dense_schemes_full_weight(self):
        for name in ("poly", "orthopoly", "rkrp"):
            sch = make_scheme(name, n=12, k_A=9)
            assert sch.omega_A == 9
            assert all(len(t) == 9 for t in sch.supports)

    def test_scs_delta_partition(self):
        sch = scs_mv(42, 6)
        assert sch.k_A == 42  # lcm(42, 6) unknowns
        assert sch.tasks_per_worker == 7  # Delta / k_A
        assert len(sch.supports) == 42 * 7
        sch2 = scs_mv(12, 9)
        assert sch2.k_A == 36  # lcm(12, 9)
        assert sch2.tasks_per_worker == 4
        assert len(sch2.supports) == 48

    def test_scs_and_class_recover(self):
        from repro.core import class_based_mv, verify_full_recovery
        for fn in (scs_mv, class_based_mv):
            ok, chk, fail = verify_full_recovery(fn(42, 6), seed=0,
                                                 max_patterns=40)
            assert ok, (fn.__name__, fail, chk)

    def test_repetition_not_threshold_optimal(self):
        sch = make_scheme("repetition", n=6, k_A=4)
        assert not sch.threshold_optimal


class TestHetero:
    def test_example4(self):
        """Example 4: capacities (3,2,2,1,1,1,1,1) -> n=12 virtual."""
        sys = make_hetero_system([3, 2, 2, 1, 1, 1, 1, 1])
        assert sys.n == 12 and sys.n_bar == 8
        assert sys.virtual_of[0] == (0, 1, 2)
        assert sys.virtual_of[1] == (3, 4)
        # k_A = sum of first 5 capacities = 9, s = 3 (paper's numbers)
        k_A = sum(sys.capacities[:5])
        s = sum(sys.capacities[5:])
        assert (k_A, s) == (9, 3)

    @given(st.lists(st.integers(1, 4), min_size=3, max_size=10))
    @settings(max_examples=60, deadline=None)
    def test_virtualisation_partition(self, caps):
        sys = make_hetero_system(caps)
        flat = [v for grp in sys.virtual_of for v in grp]
        assert flat == list(range(sys.n))
        assert sorted(sys.capacities, reverse=True) == list(sys.capacities)
