"""Validation of the analytic roofline models against XLA ground truth.

Strategy: with n_groups == 1 the layer scan has trip count 1, so XLA's
cost_analysis (which counts while bodies once) is exact -- we compare
the analytic forward-FLOP formulas against it on one config per family.
XLA additionally counts elementwise/softmax flops, so agreement is
checked as analytic/matmul-dominated ratio in [0.8, 1.15].
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.flops import (
    _attn_layer_fwd,
    _logits_fwd,
    _mamba_fwd,
    _mlp_fwd,
    _moe_fwd,
    _stack_fwd,
    cell_flops,
)
from repro.analysis.hlo import collective_bytes_loop_aware
from repro.configs import SHAPES, get_config, get_smoke_config
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig, SSMConfig
from repro.models import build_model


def xla_fwd_flops(cfg, b, s):
    model = build_model(cfg, dtype=jnp.float32)
    pspecs = jax.eval_shape(model.init, jax.random.key(0))
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}

    def fwd(params, batch):
        logits, _ = model.forward(params, batch["tokens"])
        return logits.sum()

    comp = jax.jit(fwd).lower(pspecs, batch).compile()
    cost = comp.cost_analysis()
    if isinstance(cost, list):   # older jax wrapped it per-computation
        cost = cost[0]
    return cost["flops"]


class TestAnalyticVsXLA:
    @pytest.mark.parametrize("arch_cfg", [
        ModelConfig(name="t-dense", family="dense", n_layers=1, d_model=128,
                    d_ff=256, vocab=512,
                    attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32),
                    tie_embeddings=True, remat="none", attn_impl="plain"),
        ModelConfig(name="t-ssm", family="ssm", n_layers=1, d_model=128,
                    d_ff=0, vocab=512, layer_pattern=("M",),
                    ssm=SSMConfig(d_state=32, head_dim=32, expand=2, chunk=32),
                    tie_embeddings=True, remat="none"),
    ])
    def test_fwd_flops_close(self, arch_cfg):
        b, s = 2, 64
        xla = xla_fwd_flops(arch_cfg, b, s)
        analytic = _stack_fwd(arch_cfg, b, s, s) + _logits_fwd(arch_cfg, b, s)
        ratio = analytic / xla
        assert 0.8 <= ratio <= 1.15, (analytic, xla, ratio)

    def test_moe_flops_close(self):
        cfg = ModelConfig(
            name="t-moe", family="moe", n_layers=1, d_model=128, d_ff=64,
            vocab=512, attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32),
            moe=MoEConfig(n_experts=8, top_k=2, d_expert=64),
            tie_embeddings=True, remat="none", attn_impl="plain")
        b, s = 2, 64
        xla = xla_fwd_flops(cfg, b, s)
        analytic = _stack_fwd(cfg, b, s, s) + _logits_fwd(cfg, b, s)
        ratio = analytic / xla
        # the sort-based dispatch adds non-matmul work XLA counts
        assert 0.7 <= ratio <= 1.2, (analytic, xla, ratio)


class TestCellFlops:
    def test_train_flops_scale_6nd(self):
        """Dense archs: analytic total within ~2.5x of 6ND at 4k (extra =
        attention quadratic term + remat + full-S^2 masking)."""
        for arch in ("qwen3-14b", "phi3-mini-3.8b"):
            cfg = get_config(arch)
            rep = cell_flops(cfg, SHAPES["train_4k"])
            assert 1.0 < rep.total / rep.model_flops < 2.6, \
                (arch, rep.total / rep.model_flops)

    def test_decode_flops_small(self):
        cfg = get_config("qwen3-14b")
        rep = cell_flops(cfg, SHAPES["decode_32k"])
        # decode step ~ 2*N*B plus attention reads
        assert rep.model_flops == 2.0 * cfg.active_param_count() * 128

    def test_moe_capacity_waste_visible(self):
        cfg = get_config("kimi-k2-1t-a32b")
        rep = cell_flops(cfg, SHAPES["train_4k"])
        assert rep.useful_ratio < 0.75  # capacity + attention + remat waste

    def test_window_reduces_decode_flops(self):
        g = get_config("gemma3-12b")
        full = g.with_(layer_pattern=("G",), n_layers=48)
        rep_local = cell_flops(g, SHAPES["decode_32k"])
        rep_full = cell_flops(full, SHAPES["decode_32k"])
        assert rep_local.total < rep_full.total


class TestHloParser:
    def test_loop_multiplication_real_program(self):
        def body(c, _):
            return c * 2.0, None

        def f(x):
            out, _ = jax.lax.scan(body, x, None, length=7)
            return out

        comp = jax.jit(f).lower(
            jax.ShapeDtypeStruct((8,), jnp.float32)).compile()
        res = collective_bytes_loop_aware(comp.as_text())
        assert all(v == 0 for k, v in res.items() if k != "counts")

    def test_synthetic_nested(self):
        text = """
HloModule t

%ib.1 (x: s32[]) -> s32[] {
  %ar2 = bf16[32]{0} all-to-all(%y)
}

%ic.1 (x: s32[]) -> pred[] {
  %c2 = s32[] constant(3)
}

%ob.1 (x: s32[]) -> s32[] {
  %w2 = s32[] while(%q), condition=%ic.1, body=%ib.1
}

%oc.1 (x: s32[]) -> pred[] {
  %c3 = s32[] constant(5)
}

ENTRY %m.2 (p: s32[]) -> s32[] {
  %w3 = s32[] while(%p), condition=%oc.1, body=%ob.1
}
"""
        out = collective_bytes_loop_aware(text)
        assert out["all-to-all"] == 5 * 3 * 32 * 2
        assert out["counts"]["all-to-all"] == 15
