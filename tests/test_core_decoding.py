"""Recovery-threshold (Theorems 1-2) and numerics tests for decoding."""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    CodedOperator,
    coded_matmat,
    coded_matvec,
    cyclic31_mv,
    decode,
    is_recoverable,
    proposed_mm,
    proposed_mv,
    repetition_mv,
    stability_report,
    system_matrix,
    verify_full_recovery,
)


class TestTheorem1:
    """Alg. 1 is resilient to ANY s = n - k_A stragglers."""

    @pytest.mark.parametrize("n,k", [(6, 4), (12, 9), (10, 7), (9, 6), (8, 4)])
    def test_exhaustive_recovery(self, n, k):
        sch = proposed_mv(n, k)
        G = system_matrix(sch, seed=3)
        for pat in itertools.combinations(range(n), n - k):
            alive = [w for w in range(n) if w not in pat][:k]
            assert is_recoverable(G, alive), (n, k, pat)

    @given(st.integers(1, 8), st.data())
    @settings(max_examples=40, deadline=None)
    def test_sampled_recovery_property(self, s, data):
        k = data.draw(st.integers(max(s, 2), min(s * s + s + 4, 24)))
        n = k + s
        sch = proposed_mv(n, k)
        ok, checked, failed = verify_full_recovery(sch, seed=11, max_patterns=200)
        assert ok, (n, k, failed, checked)

    def test_repetition_fails_some_pattern(self):
        """Sanity: the weight-1 repetition scheme is NOT resilient to all
        patterns (it misses when both copies of a block straggle)."""
        sch = repetition_mv(8, 4)
        G = system_matrix(sch, seed=0)
        bad = [0, 4]  # both copies of block 0
        alive = [w for w in range(8) if w not in bad][:4]
        assert not is_recoverable(G, alive)


class TestTheorem2:
    @pytest.mark.parametrize("n,ka,kb", [(20, 4, 4), (18, 4, 4), (12, 3, 3),
                                         (11, 3, 3), (42, 6, 6)])
    def test_recovery(self, n, ka, kb):
        sch = proposed_mm(n, ka, kb)
        ok, checked, failed = verify_full_recovery(sch, seed=5, max_patterns=600)
        assert ok, (n, ka, kb, failed, checked)

    def test_exhaustive_small(self):
        sch = proposed_mm(11, 3, 3)  # C(11,2) = 55 patterns
        G = system_matrix(sch, seed=1)
        for pat in itertools.combinations(range(11), 2):
            alive = [w for w in range(11) if w not in pat][:9]
            assert is_recoverable(G, alive)


class TestDecodeNumerics:
    def test_decode_exact_square(self):
        rng = np.random.default_rng(0)
        sch = proposed_mv(12, 9)
        G = system_matrix(sch, seed=2)
        U = rng.standard_normal((9, 17))
        Y = G @ U
        rows = list(range(1, 10))
        rec = decode(G, rows, Y)
        np.testing.assert_allclose(rec, U, rtol=1e-8, atol=1e-10)

    def test_decode_overdetermined(self):
        rng = np.random.default_rng(1)
        sch = proposed_mv(12, 9)
        G = system_matrix(sch, seed=2)
        U = rng.standard_normal((9, 5))
        Y = G @ U
        rec = decode(G, list(range(12)), Y)
        np.testing.assert_allclose(rec, U, rtol=1e-8, atol=1e-10)

    def test_kappa_orders(self):
        """Sparse random coding is far better conditioned than the
        Vandermonde polynomial code (Table III trend)."""
        from repro.core import poly_mv
        n, k = 16, 12
        prop = stability_report(proposed_mv(n, k), seed=0, max_patterns=128)
        poly = stability_report(poly_mv(n, k), seed=0, max_patterns=128)
        assert prop.kappa_worst < poly.kappa_worst / 10


class TestEndToEndJax:
    def test_matvec_all_patterns(self):
        rng = np.random.default_rng(0)
        sch = proposed_mv(6, 4)
        A = jnp.asarray(rng.standard_normal((24, 20)).astype(np.float64))
        x = jnp.asarray(rng.standard_normal(24))
        expected = np.asarray(A.T @ x)
        for pat in itertools.combinations(range(6), 2):
            done = np.ones(6, bool)
            done[list(pat)] = False
            y = coded_matvec(A, x, sch, seed=4, done=jnp.asarray(done))
            np.testing.assert_allclose(np.asarray(y), expected, rtol=2e-4, atol=1e-5)

    def test_matmat_with_padding(self):
        """Non-divisible dims are zero-padded and cropped transparently."""
        rng = np.random.default_rng(2)
        sch = proposed_mm(20, 4, 4)
        A = jnp.asarray(rng.standard_normal((30, 27)))   # 27 % 4 != 0
        B = jnp.asarray(rng.standard_normal((30, 18)))   # 18 % 4 != 0
        done = np.ones(20, bool)
        done[[3, 7, 12, 16]] = False
        out = coded_matmat(A, B, sch, seed=0, done=jnp.asarray(done))
        np.testing.assert_allclose(np.asarray(out), np.asarray(A.T @ B),
                                   rtol=2e-4, atol=2e-4)

    def test_operator_batched(self):
        rng = np.random.default_rng(3)
        sch = proposed_mv(12, 9)
        A = jnp.asarray(rng.standard_normal((36, 45)))
        op = CodedOperator.build(A, sch, seed=1)
        xb = jnp.asarray(rng.standard_normal((5, 36)))
        done = np.ones(12, bool)
        done[[0, 5, 9]] = False
        yb = op.apply(xb, jnp.asarray(done))
        np.testing.assert_allclose(np.asarray(yb), np.asarray(xb @ A),
                                   rtol=2e-4, atol=2e-4)

    def test_cyclic31_also_recovers_but_heavier(self):
        """Both schemes recover; ours uses strictly lower weight."""
        rng = np.random.default_rng(4)
        ours, theirs = proposed_mv(12, 9), cyclic31_mv(12, 9)
        assert ours.omega_A == 3 and theirs.omega_A == 4
        A = jnp.asarray(rng.standard_normal((18, 18)))
        x = jnp.asarray(rng.standard_normal(18))
        done = np.ones(12, bool)
        done[[1, 2, 3]] = False
        for sch in (ours, theirs):
            y = coded_matvec(A, x, sch, seed=0, done=jnp.asarray(done))
            np.testing.assert_allclose(np.asarray(y), np.asarray(A.T @ x),
                                       rtol=2e-4, atol=1e-5)

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_property_random_pattern_mv(self, seed):
        """Property: for ANY straggler pattern of size s, decode is exact."""
        rng = np.random.default_rng(seed)
        sch = proposed_mv(10, 7)
        A = jnp.asarray(rng.standard_normal((16, 14)))
        x = jnp.asarray(rng.standard_normal(16))
        pat = rng.choice(10, size=3, replace=False)
        done = np.ones(10, bool)
        done[pat] = False
        y = coded_matvec(A, x, sch, seed=seed % 17, done=jnp.asarray(done))
        # fp32 decode of a random k x k system: allow conditioning noise
        np.testing.assert_allclose(np.asarray(y), np.asarray(A.T @ x),
                                   rtol=2e-2, atol=2e-2)
