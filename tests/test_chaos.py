"""Chaos harness, retry policy, and wire-v4 membership codec tests.

The elastic-fleet PR's contract, pinned from three sides:

  * ``RetryPolicy`` -- deterministic backoff schedules (same seed, same
    sleeps), bounded attempts, wall budgets;
  * wire v4 -- join/leave/welcome/drop frames round-trip, and the
    capacity-proportional shard cut mirrors ``make_hetero_system``'s
    contiguous layout;
  * ``run_chaos`` -- scripted fault storms against a live fleet resolve
    every future (bitwise-verified within the resilience budget,
    degraded-but-correct or structured-failure past it), on every
    transport.
"""

import numpy as np
import pytest

from repro.cluster.chaos import (
    ChaosEvent,
    max_concurrent_failures,
    run_chaos,
    scripted_schedule,
)
from repro.cluster.retry import (
    ENV_RETRY_MAX_ATTEMPTS,
    RetryPolicy,
    default_max_attempts,
)
from repro.cluster.wire import (
    WorkerJoin,
    WorkerLeave,
    _host_virtuals,
    decode_event,
    drop_record,
    hello_record,
    welcome_record,
)


# ---------------------------------------------------------------------------
# RetryPolicy
# ---------------------------------------------------------------------------


class TestRetryPolicy:
    def test_backoff_is_deterministic_and_capped(self):
        p = RetryPolicy(base_s=0.1, factor=2.0, max_backoff_s=0.5, seed=7)
        a = [p.backoff_s(i) for i in range(1, 8)]
        b = [p.backoff_s(i) for i in range(1, 8)]
        assert a == b                       # same (seed, attempt) replays
        assert all(x <= 0.5 * 1.25 for x in a)      # cap + jitter bound
        q = RetryPolicy(base_s=0.1, factor=2.0, max_backoff_s=0.5, seed=8)
        assert [q.backoff_s(i) for i in range(1, 8)] != a

    def test_call_retries_then_succeeds(self):
        attempts = []

        def flaky():
            attempts.append(1)
            if len(attempts) < 3:
                raise ConnectionError("not yet")
            return "ok"

        slept = []
        p = RetryPolicy(max_attempts=5, base_s=0.01, jitter=0.0)
        out = p.call(flaky, sleep=slept.append)
        assert out == "ok"
        assert len(attempts) == 3
        assert slept == [0.01, 0.02]        # exponential, no jitter

    def test_call_exhausts_attempts_and_reraises(self):
        p = RetryPolicy(max_attempts=3, base_s=0.0, jitter=0.0)
        with pytest.raises(ConnectionError, match="always"):
            p.call(lambda: (_ for _ in ()).throw(ConnectionError("always")),
                   sleep=lambda s: None)

    def test_non_retryable_error_propagates_immediately(self):
        calls = []

        def boom():
            calls.append(1)
            raise ValueError("not transient")

        p = RetryPolicy(max_attempts=5, base_s=0.0)
        with pytest.raises(ValueError):
            p.call(boom, sleep=lambda s: None)
        assert len(calls) == 1

    def test_total_timeout_bounds_the_wall_budget(self):
        now = [0.0]

        def clock():
            return now[0]

        def sleep(s):
            now[0] += s

        def always_fail():
            now[0] += 0.05
            raise TimeoutError("slow op")

        p = RetryPolicy(max_attempts=0, base_s=0.1, jitter=0.0,
                        total_timeout_s=1.0)
        with pytest.raises(TimeoutError):
            p.call(always_fail, clock=clock, sleep=sleep)
        assert now[0] <= 1.5                # stopped near the budget

    def test_env_var_sets_attempt_default(self, monkeypatch):
        monkeypatch.delenv(ENV_RETRY_MAX_ATTEMPTS, raising=False)
        assert default_max_attempts() == 5
        monkeypatch.setenv(ENV_RETRY_MAX_ATTEMPTS, "2")
        assert default_max_attempts() == 2
        attempts = []
        p = RetryPolicy(base_s=0.0)         # max_attempts=None -> env

        def fail():
            attempts.append(1)
            raise ConnectionError("x")

        with pytest.raises(ConnectionError):
            p.call(fail, sleep=lambda s: None)
        assert len(attempts) == 2

    def test_dial_retry_gives_up_at_max_dial_s(self):
        import time

        from repro.cluster.worker import run_remote_worker

        t0 = time.perf_counter()
        with pytest.raises((ConnectionError, OSError, TimeoutError)):
            # nothing listens on this port: the dial loop must retry
            # with backoff and give up at the wall cap, not instantly
            # and not forever
            run_remote_worker("127.0.0.1", 1, 0, max_dial_s=1.0)
        dt = time.perf_counter() - t0
        assert dt < 10.0


# ---------------------------------------------------------------------------
# Wire v4: membership records + capacity-proportional shard cut
# ---------------------------------------------------------------------------


class TestWireV4:
    def test_join_leave_records_roundtrip(self):
        j = decode_event(WorkerJoin(worker=7, capacity=3).encode())
        assert isinstance(j, WorkerJoin)
        assert (j.worker, j.capacity) == (7, 3)
        lv = decode_event(WorkerLeave(worker=2, reason="battery").encode())
        assert isinstance(lv, WorkerLeave)
        assert (lv.worker, lv.reason) == (2, "battery")

    def test_hello_welcome_drop_frames(self):
        h = decode_event(hello_record(4, join=True))
        assert h["record"] == "hello"
        assert h["worker"] == 4
        assert h["join"] is True
        w = decode_event(welcome_record(4, plans=2))
        assert (w["record"], w["plans"]) == ("welcome", 2)
        # drop is coordinator->worker: it decodes as a meta dict on the
        # worker side (the serve loop demuxes on record)
        from repro.cluster.wire import decode_record

        meta, _ = decode_record(drop_record(9))
        assert (meta["record"], meta["plan"]) == ("drop", 9)

    def test_host_virtuals_uniform_round_robin(self):
        cut = _host_virtuals(8, 4)
        assert cut == [[0, 4], [1, 5], [2, 6], [3, 7]]

    def test_host_virtuals_capacity_cut_matches_hetero_layout(self):
        from repro.core.assignment import make_hetero_system

        caps = [2, 1, 3]
        sys_ = make_hetero_system(caps)
        cut = _host_virtuals(sys_.n, len(caps), capacities=caps)
        # every virtual id owned exactly once, contiguously per host
        owned = sorted(v for vs in cut for v in vs)
        assert owned == list(range(sys_.n))
        for vs in cut:
            assert vs == list(range(vs[0], vs[0] + len(vs)))
        # the largest-capacity host owns the largest contiguous range,
        # mirroring make_hetero_system's descending-capacity order
        assert len(cut[2]) >= len(cut[0]) >= len(cut[1])

    def test_shard_plan_capacities_cut(self):
        import jax.numpy as jnp

        from repro.api import compile_plan
        from repro.cluster.wire import shard_plan

        rng = np.random.default_rng(0)
        A = jnp.asarray(rng.standard_normal((128, 64)).astype(np.float32))
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        shards = shard_plan(plan, 3, capacities=[1, 3, 2])
        rows = sorted(r for s_ in shards for r in s_.task_rows)
        assert rows == list(range(plan.n_tasks))    # exact partition
        sizes = {s_.worker: len(s_.task_rows) for s_ in shards}
        # capacity-proportional: host 1 (cap 3) gets the most rows,
        # host 0 (cap 1) the fewest
        assert sizes[1] >= sizes[2] >= sizes[0]


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


class TestSchedules:
    def test_scripted_schedule_is_deterministic(self):
        a = scripted_schedule(seed=9, n=6, s=2, duration=3.0)
        b = scripted_schedule(seed=9, n=6, s=2, duration=3.0)
        assert [e.__dict__ for e in a] == [e.__dict__ for e in b]
        c = scripted_schedule(seed=10, n=6, s=2, duration=3.0)
        assert [e.__dict__ for e in a] != [e.__dict__ for e in c]

    def test_max_concurrent_failures_counts_overlap(self):
        sched = [
            ChaosEvent(kind="kill", t0=0.0, t1=2.0, worker=0),
            ChaosEvent(kind="hang", t0=1.0, t1=3.0, worker=1),
            ChaosEvent(kind="slow", t0=0.5, t1=2.5, worker=2),  # not a failure
            ChaosEvent(kind="kill", t0=4.0, t1=5.0, worker=3),
        ]
        assert max_concurrent_failures(sched) == 2

    def test_point_events_count_until_reconnect(self):
        sched = [
            ChaosEvent(kind="garble", t0=1.0, worker=0),
            ChaosEvent(kind="kill", t0=1.5, t1=2.0, worker=1),
            ChaosEvent(kind="reconnect", t0=1.2, worker=0),
        ]
        # the garble heals at 1.2, before the kill opens at 1.5
        assert max_concurrent_failures(sched) == 1

    def test_schedule_respects_failure_budget(self):
        for seed in range(5):
            sched = scripted_schedule(seed=seed, n=8, s=2, duration=4.0,
                                      n_events=10, budget=2)
            assert max_concurrent_failures(sched) <= 2


# ---------------------------------------------------------------------------
# Chaos runs
# ---------------------------------------------------------------------------


class TestChaosRuns:
    def test_memory_within_budget_all_resolve_bitwise(self):
        # one of everything, never more than s=2 concurrent failures;
        # run_chaos itself asserts every resolved value is bitwise the
        # local replay of its observed pattern and allclose to the
        # fault-free reference, and that zero futures failed
        storm = [
            ChaosEvent(kind="slow", t0=0.2, t1=1.0, worker=2, delay_s=0.1),
            ChaosEvent(kind="kill", t0=0.5, t1=1.2, worker=1),
            ChaosEvent(kind="join", t0=0.8),
            ChaosEvent(kind="leave", t0=1.1, worker=3),
            ChaosEvent(kind="reconnect", t0=1.6, worker=1),
        ]
        assert max_concurrent_failures(storm) <= 2
        res = run_chaos(storm, transport="memory", n=6, s=2, seed=0,
                        calls=16, spacing_s=0.1, warmup_s=3.0)
        counts = res.counts()
        assert counts["failed"] == 0
        assert counts["clean"] + counts["degraded"] == 16
        assert all(o.bitwise for o in res.outcomes)
        assert all(o.correct for o in res.outcomes)
        # the scripted joiner ended up serving the attached plan
        assert res.joiner_serving is True
        # kills re-homed / re-encoded: the journal shows recovery work
        kinds = {e["kind"] for e in res.events}
        assert "join" in kinds
        assert "death" in kinds or "suspect" in kinds

    def test_memory_past_budget_degrades_never_hangs(self):
        # three concurrent kills against s=2: past the budget.  The
        # fleet must re-encode at reduced resilience (degraded futures,
        # fresh plan id) or fail fast with FleetDegraded -- run_chaos
        # would raise AssertionError on any hang
        storm = [
            ChaosEvent(kind="kill", t0=0.4, t1=2.0, worker=1),
            ChaosEvent(kind="kill", t0=0.45, t1=2.0, worker=2),
            ChaosEvent(kind="kill", t0=0.5, t1=2.0, worker=3),
        ]
        assert max_concurrent_failures(storm) == 3
        res = run_chaos(storm, transport="memory", n=6, s=2, seed=1,
                        calls=16, spacing_s=0.1, warmup_s=3.0)
        counts = res.counts()
        assert sum(counts.values()) == 16
        # something actually happened: recovery work is visible
        assert counts["degraded"] > 0 or counts["failed"] > 0
        # and resolved values were still verified (bitwise + allclose)
        resolved = [o for o in res.outcomes if o.outcome != "failed"]
        assert resolved, "the fleet must keep answering past the budget"
        assert all(o.bitwise and o.correct for o in resolved)
        # the re-encode shrank the encoding to the survivors.  A kill
        # only fires when a task lands inside its window, so how many
        # of the three scripted kills actually fell their worker can
        # shift with scheduler noise -- assert the invariant instead:
        # resilience shrank below the configured s=2, and k follows
        # the policy k' = min(k, n') (availability goes last)
        assert res.final_plan["n"] < 6
        assert res.final_plan["k"] == min(4, res.final_plan["n"])
        assert res.final_plan["s"] < 2

    def test_recovery_latency_is_reported_per_fault_kind(self):
        storm = [ChaosEvent(kind="kill", t0=0.3, t1=1.2, worker=0),
                 ChaosEvent(kind="reconnect", t0=1.5, worker=0)]
        res = run_chaos(storm, transport="memory", n=4, s=1, seed=2,
                        calls=10, spacing_s=0.1, warmup_s=3.0)
        lat = res.recovery_latency()
        assert "kill" in lat
        assert all(v >= 0 for v in lat["kill"])
        d = res.as_dict()
        assert "p50_s" in d["recovery_latency"]["kill"]
        assert "p99_s" in d["recovery_latency"]["kill"]

    def test_autoscaling_interleaves_with_faults(self):
        """Scripted faults and autoscaling decisions on the same fleet
        at the same time: a kill can land mid scale-up, a join mid
        drain.  run_chaos's invariants (no hang, bitwise parity of
        every resolved value, zero failures within the budget) must
        hold regardless, and the controller's decision log must show
        scaling actually happened in both directions."""
        from repro.scale import SchedulePolicy

        sched = scripted_schedule(seed=7, n=6, s=2, duration=2.0,
                                  n_events=5)
        res = run_chaos(
            sched, transport="memory", n=6, s=2, seed=7,
            calls=16, spacing_s=0.1, warmup_s=3.0,
            autoscale={"policy": SchedulePolicy([(0, 6), (0.5, 8),
                                                 (1.5, 6)]),
                       "min_members": 2, "max_members": 10,
                       "interval_s": 0.1, "cooldown_s": 0.2})
        counts = res.counts()
        assert sum(counts.values()) == 16
        if res.max_concurrent <= 2:
            assert counts["failed"] == 0
        resolved = [o for o in res.outcomes if o.outcome != "failed"]
        assert resolved
        assert all(o.bitwise and o.correct for o in resolved)
        actions = [d["action"] for d in res.autoscale]
        assert "up" in actions and "down" in actions
        # every non-hold decision carries its audit trail
        for d in res.autoscale:
            if d["action"] != "hold":
                assert d["reason"] and d["target"] >= 0

    @pytest.mark.slow
    @pytest.mark.parametrize("transport", ["pipe", "tcp", "shm"])
    def test_process_transports_survive_chaos(self, transport):
        sched = scripted_schedule(seed=3, n=4, s=1, duration=1.5,
                                  n_events=3)
        res = run_chaos(sched, transport=transport, n=4, s=1, seed=3,
                        calls=8, spacing_s=0.15, warmup_s=15.0,
                        suspect_after=1.0)
        counts = res.counts()
        assert sum(counts.values()) == 8
        resolved = [o for o in res.outcomes if o.outcome != "failed"]
        assert resolved
        assert all(o.bitwise and o.correct for o in resolved)
        if res.max_concurrent <= 1:
            assert counts["failed"] == 0
