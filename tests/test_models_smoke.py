"""Per-architecture smoke tests (reduced configs, CPU).

For every assigned arch: one forward/train step asserting output shapes
and finiteness, one gradient step, and prefill/decode-vs-forward logits
consistency (the strongest cache-correctness check).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import build_model

B, S = 2, 32
PROMPT = 8


def make_batch(cfg, rng):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder.n_frames, cfg.d_model)),
            jnp.float32)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.vision_tokens, cfg.d_model)),
            jnp.float32)
    return batch


def extra_kwargs(cfg, batch):
    if cfg.family == "audio":
        return {"frames": batch["frames"]}
    if cfg.family == "vlm":
        return {"image_embeds": batch["image_embeds"]}
    return {}


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_train_step(self, arch):
        cfg = get_smoke_config(arch)
        model = build_model(cfg, dtype=jnp.float32)
        params = model.init(jax.random.key(0))
        batch = make_batch(cfg, np.random.default_rng(1))
        loss, grads = jax.value_and_grad(model.train_loss)(params, batch)
        assert np.isfinite(float(loss))
        flat, _ = jax.tree.flatten(grads)
        assert all(np.all(np.isfinite(np.asarray(g))) for g in flat)
        # a full forward emits (B, S_total, V) finite logits
        logits, _ = model.forward(params, batch["tokens"],
                                  batch.get("image_embeds")) \
            if cfg.family != "audio" else \
            model.forward(params, batch["tokens"], batch["frames"])
        v = cfg.vision_tokens if cfg.family == "vlm" else 0
        assert logits.shape == (B, S + v, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_prefill_decode_consistency(self, arch):
        cfg = get_smoke_config(arch)
        model = build_model(cfg, dtype=jnp.float32)
        params = model.init(jax.random.key(0))
        rng = np.random.default_rng(2)
        batch = make_batch(cfg, rng)
        prompt = batch["tokens"][:, :PROMPT]
        logits_pf, cache = model.prefill(params, prompt, max_len=S,
                                         **extra_kwargs(cfg, batch))
        l1, cache = model.decode_step(params, cache,
                                      batch["tokens"][:, PROMPT:PROMPT + 1])
        l2, cache = model.decode_step(params, cache,
                                      batch["tokens"][:, PROMPT + 1:PROMPT + 2])
        full_logits, _ = model.forward(
            params, batch["tokens"][:, :PROMPT + 2],
            batch.get("image_embeds")) if cfg.family != "audio" else \
            model.forward(params, batch["tokens"][:, :PROMPT + 2],
                          batch["frames"])
        v = cfg.vision_tokens if cfg.family == "vlm" else 0
        for got, ref in [(logits_pf, full_logits[:, v + PROMPT - 1]),
                         (l1, full_logits[:, v + PROMPT]),
                         (l2, full_logits[:, v + PROMPT + 1])]:
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=5e-3, atol=5e-3)

    def test_full_config_well_formed(self, arch):
        cfg = get_config(arch)
        assert cfg.n_groups >= 1
        assert cfg.param_count() > 0
        if cfg.family in ("moe",):
            assert cfg.active_param_count() < cfg.param_count()


class TestParamScale:
    """Full configs hit their nameplate parameter counts (+-20%)."""

    @pytest.mark.parametrize("arch,nominal_b", [
        ("qwen3-14b", 14), ("phi3-medium-14b", 14), ("gemma3-12b", 12),
        ("phi3-mini-3.8b", 3.8), ("mamba2-1.3b", 1.3),
        ("phi-3-vision-4.2b", 4.2), ("granite-moe-1b-a400m", 1.3),
        ("kimi-k2-1t-a32b", 1000),
    ])
    def test_nameplate(self, arch, nominal_b):
        count = get_config(arch).param_count() / 1e9
        assert 0.75 * nominal_b <= count <= 1.35 * nominal_b, count

    def test_kimi_active(self):
        active = get_config("kimi-k2-1t-a32b").active_param_count() / 1e9
        assert 25 <= active <= 40, active
