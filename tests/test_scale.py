"""repro.scale: the autoscaling controller, policies and pools.

The controller tests are fully deterministic: a fake pool, a scripted
sensor and explicit ``step(now=...)`` ticks -- no threads, no sleeps,
no wall clock.  Hysteresis (cooldowns, watermark clamps, the
resilience-floor override), burst-up/gentle-down asymmetry and the
decision/trace logs are all asserted tick by tick.

Integration tests then close the real loop on live targets: a
``LocalPool`` growing a memory fleet (with ``grow_encodings`` the
re-encode turns new workers into capacity: ``k`` grows, ``s`` holds),
a ``ReplicaPool`` growing a router endpoint under a paused backlog,
and a ``RemotePool`` dialing standalone ``--connect`` workers into a
coordinator-mode tcp fleet.  Every value served across a scale event
is checked against the fault-free reference -- elasticity is not
allowed to cost correctness.
"""

import os
import socket
import subprocess
import sys
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import CodedFleet, compile_plan
from repro.obs import Tracer
from repro.scale import (
    Autoscaler,
    LatencySloPolicy,
    LocalPool,
    ProvisionError,
    QueueDepthPolicy,
    RemotePool,
    ReplicaPool,
    ScaleController,
    ScaleSnapshot,
    SchedulePolicy,
    WorkerPool,
)
from repro.scale.policy import (
    default_high_watermark,
    default_low_watermark,
    default_max_members,
    default_min_members,
)
from repro.serve import Router


def block_sparse(rng, t, r, zeros, bs=8, dtype=np.float32):
    mask = rng.random((t // bs, r // bs)) >= zeros
    a = rng.standard_normal((t, r)).astype(dtype)
    return a * np.kron(mask, np.ones((bs, bs), dtype))


@pytest.fixture(scope="module")
def operands():
    rng = np.random.default_rng(11)
    t, r = 256, 144
    A = jnp.asarray(block_sparse(rng, t, r, 0.98))
    xs = [np.asarray(rng.standard_normal(t), np.float32)
          for _ in range(8)]
    return A, xs


def snap(t=0.0, size=1, backlog=0.0, inflight=0.0, lat=None, floor=1):
    return ScaleSnapshot(t=t, size=size, backlog=backlog,
                         inflight=inflight, lat_ewma_ms=lat, floor=floor)


# ---------------------------------------------------------------------------
# policies (pure: one snapshot in, a desired size out)
# ---------------------------------------------------------------------------


class TestPolicies:
    def test_queue_depth_scales_to_backlog(self):
        p = QueueDepthPolicy(high=8, low=1)
        # 40 queued over 1 member: jump straight to ceil(40/8) = 5
        assert p.target(snap(size=1, backlog=40)) == 5
        # between the watermarks: no opinion
        assert p.target(snap(size=5, backlog=20)) is None
        # idle: shrink one member at a time
        assert p.target(snap(size=5, backlog=0)) == 4
        # low backlog but work still in flight: hold
        assert p.target(snap(size=5, backlog=0, inflight=3)) is None

    def test_queue_depth_validates_watermarks(self):
        with pytest.raises(ValueError, match="below"):
            QueueDepthPolicy(high=4, low=4)

    def test_latency_slo(self):
        p = LatencySloPolicy(slo_ms=100.0, shrink_frac=0.5, low=1)
        assert p.target(snap(size=2, lat=250.0, backlog=9)) == 3
        # inside the SLO but not comfortably: hold
        assert p.target(snap(size=3, lat=80.0, backlog=0)) is None
        # comfortably inside + quiet queue: shrink
        assert p.target(snap(size=3, lat=20.0, backlog=0)) == 2
        # no latency measured yet, empty queue: shrink is still safe
        assert p.target(snap(size=3, lat=None, backlog=0)) == 2
        with pytest.raises(ValueError, match="slo_ms"):
            LatencySloPolicy(slo_ms=0)

    def test_schedule_policy_steps_on_snapshot_time(self):
        p = SchedulePolicy([(0, 2), (10, 6), (20, 3)])
        assert p.target(snap(t=100.0)) == 2        # t0 anchors here
        assert p.target(snap(t=105.0)) == 2
        assert p.target(snap(t=110.0)) == 6
        assert p.target(snap(t=125.0)) == 3
        with pytest.raises(ValueError):
            SchedulePolicy([])

    def test_env_knobs_strictly_parsed(self, monkeypatch):
        monkeypatch.setenv("REPRO_SCALE_HIGH", "12")
        assert default_high_watermark() == 12
        monkeypatch.setenv("REPRO_SCALE_LOW", "0")     # 0 is legitimate
        assert default_low_watermark() == 0
        monkeypatch.setenv("REPRO_SCALE_HIGH", "bogus")
        with pytest.raises(ValueError, match="REPRO_SCALE_HIGH"):
            default_high_watermark()
        monkeypatch.setenv("REPRO_SCALE_MAX_WORKERS", "-3")
        with pytest.raises(ValueError, match="REPRO_SCALE_MAX_WORKERS"):
            default_max_members()
        monkeypatch.setenv("REPRO_SCALE_MIN_WORKERS", "0")
        with pytest.raises(ValueError, match="REPRO_SCALE_MIN_WORKERS"):
            default_min_members()


# ---------------------------------------------------------------------------
# the controller, driven tick by tick with a fake clock + pool
# ---------------------------------------------------------------------------


class FakePool(WorkerPool):
    kind = "fake"

    def __init__(self, size=1, fail_provision=False):
        super().__init__()
        self._members = list(range(size))
        self._next = size
        self.fail_provision = fail_provision

    def members(self):
        return list(self._members)

    def provision(self):
        if self.fail_provision:
            self._count("provision_failures")
            raise ProvisionError("scripted provision failure")
        w, self._next = self._next, self._next + 1
        self._members.append(w)
        self._count("provisioned")
        return w

    def decommission(self, member):
        self._members.remove(member)
        self._count("decommissioned")


def make_controller(pool, policy, signal, **kw):
    """Controller whose sensor reads the mutable ``signal`` dict and
    whose clock would *fail* if consulted -- every test tick must pass
    ``now=`` explicitly (determinism is load-bearing)."""

    def sensor(now):
        return ScaleSnapshot(t=now, size=pool.size(), **signal)

    def no_clock():
        raise AssertionError("controller consulted the wall clock")

    kw.setdefault("cooldown_s", 1.0)
    return ScaleController(pool, policy, sensor, clock=no_clock, **kw)


class TestController:
    def test_burst_up_then_cooldown(self):
        pool = FakePool(size=1)
        sig = {"backlog": 40.0}
        c = make_controller(pool, QueueDepthPolicy(high=8, low=1), sig,
                            min_members=1, max_members=8, max_step_up=2)
        d = c.step(now=0.0)
        # wants ceil(40/8)=5 but the burst cap admits 2 per tick
        assert (d.action, d.target, d.applied) == ("up", 5, 2)
        assert pool.size() == 3
        # the next tick is inside the cooldown: blocked, logged as such
        d = c.step(now=0.5)
        assert (d.action, d.reason) == ("hold", "cooldown")
        assert pool.size() == 3
        d = c.step(now=1.5)                    # cooldown expired
        assert (d.action, d.applied) == ("up", 2)
        assert pool.size() == 5

    def test_scale_down_one_member_per_tick_newest_first(self):
        pool = FakePool(size=4)
        sig = {"backlog": 0.0}
        c = make_controller(pool, QueueDepthPolicy(high=8, low=1), sig,
                            min_members=1, max_members=8)
        d = c.step(now=0.0)
        assert (d.action, d.applied) == ("down", -1)
        assert pool.members() == [0, 1, 2]     # newest went first
        d = c.step(now=10.0)
        assert pool.members() == [0, 1]

    def test_clamps_to_min_and_max(self):
        pool = FakePool(size=2)
        sig = {"backlog": 10_000.0}
        c = make_controller(pool, QueueDepthPolicy(high=8, low=1), sig,
                            min_members=2, max_members=4, max_step_up=8)
        d = c.step(now=0.0)
        assert d.target == 4 and pool.size() == 4
        sig["backlog"] = 0.0
        c.step(now=10.0)
        c.step(now=20.0)
        d = c.step(now=30.0)
        # the floor: pool never shrinks below min_members
        assert pool.size() == 2
        assert (d.action, d.reason) == ("hold", "at-target")

    def test_floor_restore_outranks_policy_and_cooldown_reason(self):
        pool = FakePool(size=1)
        sig = {"backlog": 0.0, "floor": 3}     # fleet.min_workers = 3
        c = make_controller(pool, QueueDepthPolicy(high=8, low=1), sig,
                            min_members=1, max_members=8, max_step_up=4)
        d = c.step(now=0.0)
        # deaths dropped the roster below the resilience floor: the
        # controller restores it even though the load says shrink
        assert (d.action, d.reason, d.applied) == ("up", "floor", 2)
        assert pool.size() == 3

    def test_provision_failure_is_logged_not_fatal(self):
        pool = FakePool(size=1, fail_provision=True)
        sig = {"backlog": 100.0}
        c = make_controller(pool, QueueDepthPolicy(high=8, low=1), sig,
                            min_members=1, max_members=8)
        d = c.step(now=0.0)
        assert d.action == "up" and not d.ok
        assert "scripted provision failure" in d.error
        assert c.counters["errors"] == 1
        # the loop keeps going: the next post-cooldown tick retries
        pool.fail_provision = False
        d = c.step(now=5.0)
        assert d.ok and d.applied > 0

    def test_every_action_lands_in_tracer_and_decision_log(self):
        tr = Tracer(capacity=64)
        pool = FakePool(size=1)
        sig = {"backlog": 40.0}
        c = make_controller(pool, QueueDepthPolicy(high=8, low=1), sig,
                            min_members=1, max_members=8, max_step_up=8,
                            tracer=tr)
        c.step(now=0.0)
        sig["backlog"] = 0.0
        c.step(now=10.0)
        c.step(now=10.5)                       # cooldown hold
        log = c.decision_log()
        assert [d["action"] for d in log] == ["up", "down", "hold"]
        marks = [e for e in tr.events() if e["name"] == "scale.decision"]
        assert [m["args"]["action"] for m in marks] == ["up", "down"]
        assert marks[0]["args"]["applied"] == 4
        m = c.metrics()
        assert m["counters"]["ups"] == 1 and m["counters"]["downs"] == 1
        assert m["last_decision"]["reason"] == "cooldown"
        assert m["pool"]["kind"] == "fake"

    def test_schedule_policy_full_sequence(self):
        pool = FakePool(size=2)
        c = make_controller(pool, SchedulePolicy([(0, 2), (5, 6), (9, 4)]),
                            {}, min_members=1, max_members=8,
                            max_step_up=8, cooldown_s=0.0)
        assert c.step(now=0.0).action == "hold"
        assert c.step(now=5.0).applied == 4
        assert c.step(now=9.0).applied == -1
        assert c.step(now=9.1).applied == -1
        assert pool.size() == 4
        assert c.step(now=9.2).action == "hold"


# ---------------------------------------------------------------------------
# pools + Autoscaler against live targets
# ---------------------------------------------------------------------------


class TestLocalPoolAndFleet:
    def test_provision_decommission_roundtrip(self, operands):
        A, xs = operands
        plan = compile_plan(A, scheme="proposed", n=4, s=1,
                            backend="packed")
        with CodedFleet(4) as fleet:
            fleet.attach(plan)
            pool = LocalPool(fleet)
            w = pool.provision()
            assert w in fleet.live_workers() and pool.size() == 5
            pool.decommission(w)
            assert w not in fleet.live_workers() and pool.size() == 4
            m = pool.metrics()
            assert m["provisioned"] == 1 and m["decommissioned"] == 1

    def test_autoscaler_grows_encoding_into_capacity(self, operands):
        A, xs = operands
        plan = compile_plan(A, scheme="proposed", n=4, s=1,
                            backend="packed")
        with CodedFleet(4, grow_encodings=True) as fleet:
            h = fleet.attach(plan)
            ref = np.asarray(h.matvec(xs[0]))
            scaler = Autoscaler(fleet,
                                policy=SchedulePolicy([(0, 4), (1, 6)]),
                                min_members=2, max_members=8,
                                cooldown_s=0.0)
            assert scaler.step(now=0.0).action == "hold"
            d = scaler.step(now=2.0)
            assert (d.action, d.applied) == ("up", 2)
            assert len(fleet.live_workers()) == 6
            deadline = time.time() + 15
            while time.time() < deadline and h.plan.n <= plan.n:
                time.sleep(0.02)
            # growth preserved the absolute straggler budget and grew
            # k, shrinking each worker's omega/k share: capacity
            assert h.plan.n > plan.n
            assert h.plan.k > plan.k
            assert h.plan.s >= plan.s
            got = np.asarray(h.matvec(xs[0]))
            np.testing.assert_allclose(got, ref, atol=1e-3, rtol=1e-3)
            scaler.close()

    def test_autoscaler_start_close_lifecycle(self, operands):
        A, xs = operands
        plan = compile_plan(A, scheme="proposed", n=4, s=1,
                            backend="packed")
        with CodedFleet(4) as fleet:
            fleet.attach(plan)
            with Autoscaler(fleet, policy=QueueDepthPolicy(high=8, low=1),
                            interval_s=0.02) as scaler:
                deadline = time.time() + 10
                while time.time() < deadline \
                        and scaler.metrics()["counters"]["ticks"] < 3:
                    time.sleep(0.02)
                assert scaler.metrics()["counters"]["ticks"] >= 3
            with pytest.raises(RuntimeError, match="closed"):
                scaler.controller.start()

    def test_autoscaler_rejects_unknown_target(self):
        with pytest.raises(TypeError, match="autoscale"):
            Autoscaler(object())


class TestReplicaPoolAndRouter:
    def test_backlog_scales_replicas_up_and_down(self, operands):
        A, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        with Router() as router:
            router.register("head", plan, replicas=1, n_workers=6)
            scaler = Autoscaler(router, endpoint="head",
                                policy=QueueDepthPolicy(high=8, low=1),
                                n_workers=6, min_members=1, max_members=3,
                                cooldown_s=0.0)
            router.pause()                     # build a visible backlog
            futs = [router.submit("head", xs[i % len(xs)])
                    for i in range(30)]
            d = scaler.step(now=0.0)
            assert d.action == "up" and scaler.pool.size() == 3
            router.resume()
            ref = np.asarray(plan.matvec(jnp.asarray(xs[0])))
            vals = [np.asarray(f.result(60)) for f in futs]
            np.testing.assert_allclose(vals[0], ref, atol=1e-3, rtol=1e-3)
            # drained: the scaler decommissions back to the floor, one
            # replica per tick, without failing a single future
            for i, now in enumerate((1.0, 2.0, 3.0)):
                scaler.step(now=now)
            assert scaler.pool.size() == 1
            assert all(f.done() for f in futs)
            scaler.close()

    def test_last_replica_is_protected(self, operands):
        A, xs = operands
        plan = compile_plan(A, scheme="proposed", n=6, s=2,
                            backend="packed")
        with Router() as router:
            router.register("head", plan, replicas=1, n_workers=6)
            pool = ReplicaPool(router, "head", n_workers=6)
            with pytest.raises(ProvisionError, match="last live replica"):
                pool.decommission(pool.members()[0])


class TestRemotePool:
    def test_dials_standalone_workers(self, operands):
        A, xs = operands
        plan = compile_plan(A, scheme="proposed", n=4, s=1,
                            backend="packed")
        probe = socket.socket()
        probe.bind(("127.0.0.1", 0))
        port = probe.getsockname()[1]
        probe.close()
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env = {**os.environ,
               "PYTHONPATH": os.pathsep.join(
                   ["src"] + os.environ.get("PYTHONPATH", "").split(
                       os.pathsep)).rstrip(os.pathsep)}
        procs = []

        def launch(worker_id, port_):
            procs.append(subprocess.Popen(
                [sys.executable, "-m", "repro.cluster.worker",
                 "--connect", f"127.0.0.1:{port_}", "--id",
                 str(worker_id)],
                env=env, cwd=root))

        for w in range(2):                     # the initial roster dials
            launch(w, port)
        try:
            with CodedFleet(2, transport="tcp",
                            transport_opts={"spawn": False,
                                            "port": port}) as fleet:
                h = fleet.attach(plan)
                ref = np.asarray(h.matvec(xs[0]))
                pool = RemotePool(fleet, launch)
                w = pool.provision()
                assert w == 2 and pool.size() == 3
                got = np.asarray(h.matvec(xs[1]))
                want = np.asarray(plan.matvec(jnp.asarray(xs[1])))
                np.testing.assert_allclose(got, want, atol=1e-3,
                                           rtol=1e-3)
                pool.decommission(w)
                assert pool.size() == 2
                np.testing.assert_allclose(
                    np.asarray(h.matvec(xs[0])), ref, atol=1e-3,
                    rtol=1e-3)
        finally:
            for p in procs:
                if p.poll() is None:
                    p.kill()

    def test_rejects_non_tcp_fleet(self):
        with CodedFleet(2) as fleet:
            with pytest.raises(ValueError, match="tcp"):
                RemotePool(fleet, lambda w, p: None)
