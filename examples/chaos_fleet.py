"""Self-healing fleet under a scripted fault storm.

A ``CodedFleet`` claims it never hangs: workers can die, go silent,
slow down, partition, leave gracefully or join mid-run, and every
submitted future still resolves -- with a value that is *bitwise* the
local replay of its round's observed pattern, or with a structured
``FleetDegraded`` naming the recovery action.  This example scripts
exactly that storm with the chaos harness and narrates what the fleet
does about it:

  * a **kill** fails a worker mid-round: its shards re-home, its rows
    requeue, and (the live set now too small for the full encoding)
    the plan **re-encodes** under a fresh plan id -- ``k`` preserved,
    resilience ``s`` shrunk: availability survives at reduced margin;
  * a **reconnect** revives the felled worker id: the fleet catches it
    up with every attached plan's shards and re-encodes back to full
    strength;
  * a **join** admits a brand-new worker: shard ownership rebalances
    off the most-loaded hosts so the newcomer serves too;
  * a **leave** drains first -- in-flight rows get a grace window on
    the leaver before the channel closes without a death notice;
  * throughout, per-worker throughput EWMAs feed the hetero-capacity
    encoder, so a measurably slow device would get fewer virtual tiles
    on the next re-encode.

    PYTHONPATH=src python examples/chaos_fleet.py
"""

import sys

sys.path.insert(0, "src")

from repro.cluster.chaos import (
    ChaosEvent,
    max_concurrent_failures,
    run_chaos,
    scripted_schedule,
)

if __name__ == "__main__":
    n, s = 6, 2

    # a hand-written storm: one of everything, inside the budget
    storm = [
        ChaosEvent(kind="slow", t0=0.3, t1=1.2, worker=2, delay_s=0.15),
        ChaosEvent(kind="kill", t0=0.6, t1=1.4, worker=1),
        ChaosEvent(kind="join", t0=0.9),
        ChaosEvent(kind="leave", t0=1.3, worker=3),
        ChaosEvent(kind="reconnect", t0=1.7, worker=1),
        ChaosEvent(kind="garble", t0=2.0, worker=4),
        ChaosEvent(kind="reconnect", t0=2.5, worker=4),
    ]
    print(f"storm: {len(storm)} events, peak concurrent failures = "
          f"{max_concurrent_failures(storm)} (budget s={s})")

    res = run_chaos(storm, transport="memory", n=n, s=s, seed=0,
                    calls=24, spacing_s=0.12, warmup_s=3.0)

    counts = res.counts()
    print(f"\nfutures: {counts['clean']} clean, {counts['degraded']} "
          f"degraded-but-correct, {counts['failed']} failed -- none hung")
    print("fleet journal:", " -> ".join(e["kind"] for e in res.events))
    print(f"final encoding: plan_id={res.final_plan['plan_id']} "
          f"n={res.final_plan['n']} k={res.final_plan['k']} "
          f"s={res.final_plan['s']}")
    if res.joiner_serving is not None:
        print(f"joiner serving the attached plan: {res.joiner_serving}")
    for kind, lat in sorted(res.recovery_latency().items()):
        print(f"recovery after {kind}: "
              f"{', '.join(f'{v * 1e3:.0f}ms' for v in lat)}")

    # the same machinery generates seeded random storms (the CI smoke):
    sched = scripted_schedule(seed=5, n=n, s=s, duration=2.0)
    res2 = run_chaos(sched, transport="memory", n=n, s=s, seed=5,
                     calls=16, spacing_s=0.1, warmup_s=3.0)
    print(f"\nseeded schedule (seed=5): {res2.counts()} under "
          f"{res2.max_concurrent} peak concurrent failures")
    print("every resolved value was bitwise-verified against the local "
          "replay of its observed pattern.")
