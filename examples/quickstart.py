"""Quickstart: sparsity-preserving coded matrix multiplication in 40 lines.

Builds the paper's Alg. 2 scheme for n=20 devices with gamma_A =
gamma_B = 1/4 (Fig. 4's system) through the scheme registry, compiles a
plan once (encoding + packed shards + automatic backend), knocks out
the worst-case s = 4 stragglers, and recovers A^T B exactly from the
fastest 16 workers.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.api import compile_plan, list_schemes
from repro.core import min_weight

rng = np.random.default_rng(0)

# --- pick a scheme from the registry ------------------------------------
print("registered mm schemes:",
      ", ".join(i.name for i in list_schemes("mm")))

# --- the paper's Fig. 4 system ------------------------------------------
n, k_A, k_B = 20, 4, 4
t, r, w = 400, 320, 240
A = rng.standard_normal((t, r)) * (rng.random((t, r)) < 0.05)
B = rng.standard_normal((t, w)) * (rng.random((t, w)) < 0.05)
print(f"A: {A.shape}, density {np.mean(A != 0):.3f}; "
      f"B: {B.shape}, density {np.mean(B != 0):.3f}")

# compile once: scheme + encoding + shards + backend (auto = density pick)
plan = compile_plan(jnp.asarray(A, jnp.float32), scheme="proposed",
                    n=n, k_A=k_A, k_B=k_B, backend="auto")
scheme, s = plan.scheme, plan.s
print(f"system: n={n} devices, k_A=k_B=4 -> resilient to s={s} stragglers")
print(f"weight: omega_A*omega_B = {scheme.omega_A}*{scheme.omega_B} "
      f"= {scheme.weight()} (lower bound {min_weight(n, s)})")
print(f"dense MDS codes would use weight k_A*k_B = {k_A * k_B}")
print(f"compiled plan: {plan.describe()}\n")

# each coded submatrix mixes only omega block-columns -> density grows by
# ~omega, not by k (the paper's whole point)
per_worker_density = 1 - (1 - 0.05) ** scheme.omega_A
print(f"coded submatrix density ~{per_worker_density:.3f} "
      f"(dense coding would give ~{1 - 0.95 ** k_A:.3f})\n")

# --- straggle any s devices, still decode exactly -------------------------
done = np.ones(n, bool)
stragglers = rng.choice(n, size=s, replace=False)
done[stragglers] = False
print(f"stragglers this round: {sorted(stragglers.tolist())}")

out = plan.matmat(jnp.asarray(B, jnp.float32), jnp.asarray(done))
err = np.max(np.abs(np.asarray(out) - A.T @ B)) / np.max(np.abs(A.T @ B))
print(f"recovered A^T B from the fastest {n - s} workers; "
      f"max rel err = {err:.2e}")
assert err < 1e-3

# the plan is compiled once -- a second round with a different straggler
# set reuses the encoded shards and hits the decode cache
done2 = np.ones(n, bool)
done2[rng.choice(n, size=s, replace=False)] = False
out2 = plan.matmat(jnp.asarray(B, jnp.float32), jnp.asarray(done2))
err2 = np.max(np.abs(np.asarray(out2) - A.T @ B)) / np.max(np.abs(A.T @ B))
assert err2 < 1e-3
print("OK")
