"""One fleet, many consumers: the LM head and the gradient aggregator
serving off the same persistent worker session.

Before the fleet redesign every consumer of coded compute hoarded its
own cluster (one transport, one worker set, one blocking round at a
time).  Here a single ``CodedFleet`` owns the workers; the serving
engine's coded LM head and a ``CodedAggregator`` both *attach* to it --
their shards co-hosted on the same devices, their rounds multiplexed
over one long-lived dispatcher loop:

  * **futures + pipelining** -- a burst of decode-step matvecs is
    submitted as ``CodedFuture``s and collected later, with several
    rounds in flight at once;
  * **microbatching** -- queued matvecs coalesce into wider rounds
    (operand columns packed side by side, the MM-regime amortization);
    the per-round reports show multiple calls resolved per round;
  * **shared capacity** -- gradient aggregation rounds interleave with
    the head's rounds on the same workers, no second fleet required;
  * ``engine.close()`` only *detaches* the head's plan -- the fleet
    keeps serving the aggregator until its owner closes it.

    PYTHONPATH=src python examples/fleet_serve.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import compile_plan
from repro.api.fleet import CodedFleet
from repro.configs import get_smoke_config
from repro.configs.base import CodedConfig
from repro.models import build_model
from repro.parallel.coded_grads import CodedAggregator
from repro.serve import ServeEngine

rng = np.random.default_rng(0)
n, s = 6, 2

# --- one session for everything --------------------------------------------
fleet = CodedFleet(n, transport="memory", max_inflight=8)

# --- consumer 1: the serve engine's coded LM head ---------------------------
cfg = get_smoke_config("qwen3-14b")
model = build_model(cfg, dtype=jnp.float32)
params = model.init(jax.random.key(0))
engine = ServeEngine(
    model, params, cfg, batch_size=4, max_len=64,
    coded=CodedConfig(enabled=True, n_workers=n, stragglers=s, fleet=fleet))
head = params["embed"].T if cfg.tie_embeddings else params["head"]
print(f"head plan attached: scheme={engine.coded.scheme.name} n={n} s={s} "
      f"plan_id={engine.coded_cluster.plan_id}")

# --- consumer 2: coded gradient aggregation on the SAME workers -------------
agg = CodedAggregator.build(n, s, seed=0)
agg_handle = agg.to_cluster(fleet=fleet)
print(f"aggregator attached: plan_id={agg_handle.plan_id} "
      f"(same transport: {fleet.transport_name})\n")

# --- a burst of decode steps as futures, gradients interleaved --------------
steps = 12
hiddens = [jnp.asarray(rng.standard_normal((4, cfg.d_model)), jnp.float32)
           for _ in range(steps)]
shard_grads = [{"w": jnp.asarray(rng.standard_normal(64), jnp.float32)}
               for _ in range(n - s)]
payloads = [agg.worker_payload(w, shard_grads) for w in range(n)]

t0 = time.perf_counter()
logit_futs = [engine.coded_cluster.submit_matvec(h) for h in hiddens]
grad_fut = agg_handle.submit_aggregate(payloads)   # interleaves with the head
logits = [f.result() for f in logit_futs]
grad = grad_fut.result()
elapsed = time.perf_counter() - t0

worst = max(float(jnp.abs(lg - hd @ head).max())
            for lg, hd in zip(logits, hiddens))
want = np.asarray(sum(g["w"] for g in shard_grads))
print(f"{steps} head matvecs + 1 aggregate in {elapsed * 1e3:.1f} ms "
      f"({(steps + 1) / elapsed:.0f} calls/s)")
print(f"head max |coded - direct| = {worst:.2e}")
print(f"aggregate max |err| = {np.abs(np.asarray(grad['w']) - want).max():.2e}")
rounds = list(engine.coded_cluster.reports)
print(f"head rounds: {len(rounds)} for {steps} calls "
      f"(microbatch coalesced: {[r.calls for r in rounds]})\n")

# --- engine close detaches; the fleet keeps serving the aggregator ----------
engine.close()
grad2 = agg_handle.aggregate(payloads)
print(f"after engine.close(): aggregator still serving "
      f"(err {np.abs(np.asarray(grad2['w']) - want).max():.2e})")
fleet.close()
print("fleet closed: workers reaped, futures accounted for.")
