"""The serve front door: two tenants, one endpoint, a replica pair.

The fleet coalesces queued matvecs under a *static* width cap -- tuned
for one offered load only.  The ``Router`` fronts fleet replicas with
named endpoints and decides the width itself: per-tenant weighted-fair
queues (service converges to the weight ratios under contention, no
starvation), **adaptive microbatching** (the effective round width
follows the backlog: collapses at low load so solo calls skip the
collection window, ramps at high load so decode amortization kicks in),
and least-loaded replica balancing.  Every routed result is bitwise
identical to the same call submitted directly against a fleet handle --
batches go down as one round with per-call decode slices.

Here a "pro" tenant (weight 3) and a "free" tenant (weight 1) share one
``lm-head`` endpoint over two replica fleets:

  * a contended burst shows ~3:1 service in the dispatch log and an
    adaptive width ramp;
  * a quiet stretch shows the width collapsing back and solo-call
    latency matching a direct fleet call;
  * ``ServeEngine`` plugs in via ``CodedConfig(router=...)`` -- the
    engine's coded LM head becomes just another tenant.

    PYTHONPATH=src python examples/router_serve.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.api import compile_plan
from repro.serve import Router

rng = np.random.default_rng(0)
n, s, b = 8, 2, 4
A = jnp.asarray(rng.standard_normal((512, 768)).astype(np.float32))
plan = compile_plan(A, scheme="proposed", n=n, s=s)

# --- one endpoint, two replica fleets, two tenants --------------------------
router = Router(batch_wait_s=0.004)
# max_cols caps the adaptive round width: wider rounds amortize more
# decode weight but make fair-share granularity coarser -- 32 keeps the
# burst below legible in the dispatch log
router.register("lm-head", plan, replicas=2, n_workers=n,
                transport="memory", max_cols=32)
router.set_tenant("pro", weight=3.0)
router.set_tenant("free", weight=1.0, deadline=5.0)
print(f"endpoint lm-head: replicas=2 adaptive width in "
      f"[{router.metrics()['endpoints']['lm-head']['min_cols']}, "
      f"{router.metrics()['endpoints']['lm-head']['max_cols']}]")

xs = [jnp.asarray(rng.standard_normal((b, 512)), jnp.float32)
      for _ in range(32)]
router.call("lm-head", xs[0], tenant="pro")          # warm both replicas
router.call("lm-head", xs[0], tenant="free")

# --- contended burst: weighted-fair service + adaptive width ramp -----------
log_before = len(router.dispatch_log("lm-head"))
router.pause()                                       # build a backlog
futs = [(tn, router.submit("lm-head", x, tenant=tn))
        for x in xs for tn in ("pro", "free")]
t0 = time.perf_counter()
router.resume()
outs = {id(f): np.asarray(f.result(60)) for _, f in futs}
elapsed = time.perf_counter() - t0
log = router.dispatch_log("lm-head")[log_before:]
# fairness shows while BOTH tenants still queue (the drain tail is
# whoever's backlog outlived the other): cumulative service at the
# point the first tenant's last column dispatches
cols, backlog = {}, dict.fromkeys(("pro", "free"), len(xs) * b)
for e in log:
    if min(backlog.values()) <= 0:
        break
    cols[e["tenant"]] = cols.get(e["tenant"], 0) + e["cols"]
    backlog[e["tenant"]] -= e["cols"]
print(f"\nburst: {len(futs)} calls ({len(futs) * b} cols) in "
      f"{elapsed * 1e3:.1f} ms over {len(log)} rounds")
print(f"served cols while contended, pro:free = "
      f"{cols.get('pro', 0)}:{cols.get('free', 0)} (weights 3:1)")
print(f"width ramp: {[e['cols'] for e in log]} "
      f"(replicas used: {sorted({e['replica'] for e in log})})")

# --- every routed result is bitwise-identical to a direct handle call -------
ep = router.metrics()["endpoints"]["lm-head"]
handle_fleetless = None
tn0, f0 = futs[0]
rep = f0.report                                      # observed pattern
direct = plan.to_cluster(n, transport="memory")
try:
    want = np.asarray(direct.matvec(xs[0], done=rep.pattern))
finally:
    direct.shutdown()
print(f"parity vs direct replay of the observed pattern: "
      f"{'bitwise' if np.array_equal(outs[id(f0)], want) else 'DIVERGED'}")

# --- quiet stretch: the width collapses, solo calls fly solo ----------------
lat = []
for i, x in enumerate(2 * xs[:8]):
    t1 = time.perf_counter()
    router.call("lm-head", x, tenant="free")
    lat.append(time.perf_counter() - t1)
m = router.metrics()["endpoints"]["lm-head"]
p50 = np.percentile(np.array(lat[-8:]) * 1e3, 50)    # post-collapse tail
print(f"\nquiet: solo-call p50 {p50:.2f} ms once the width walks back "
      f"down to {m['width']} (no collection window at low load)")
print(f"tenant counters: "
      f"{ {t: v['counters']['resolved'] for t, v in m['tenants'].items()} }")

router.close()
print("\nrouter closed: queues drained, endpoints detached, owned replica "
      "fleets reaped.")
