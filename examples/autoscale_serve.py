"""The closed load->capacity loop: an autoscaled coded serve path.

PR 6 made the fleet roster *elastic* (live join/leave + re-encode) and
PRs 7-8 gave it *sensors* (router/fleet metrics, traced per-worker
compute rates).  ``repro.scale`` closes the loop: a deterministic
controller watches the load signal and drives a provisioner pool, so
capacity follows demand without anyone calling ``add_worker`` by hand.

Four acts:

  * **load ramp** -- a paused router builds a backlog; the
    ``QueueDepthPolicy`` watermark trips and the ``ReplicaPool``
    provisions replica fleets up to the ceiling;
  * **scale-up serves the burst** -- every queued call resolves, and
    each result matches the plain ``plan.matvec`` reference;
  * **scale-down** -- once the backlog drains and nothing is in
    flight, the controller sheds one replica per tick (newest first,
    cooldown between actions) back to the floor, and the decision log
    shows the whole story;
  * **straggler storm** -- a fleet with a seeded slow worker measures
    it via traced compute rates; when a scheduled scale-up grows the
    roster, ``grow_encodings=True`` re-encodes to a *larger* code cut
    by those measured rates, so the grown capacity raises ``k`` (more
    parallelism per round, instead of padding redundancy) and the slow
    worker owns the fewest rows of the new hetero layout.

    PYTHONPATH=src python examples/autoscale_serve.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.api import compile_plan
from repro.cluster import CodedFleet
from repro.cluster.faults import adversarial_faults
from repro.obs import Tracer
from repro.scale import Autoscaler, QueueDepthPolicy, SchedulePolicy
from repro.serve import Router

rng = np.random.default_rng(0)

# --- act 1: load ramp trips the queue-depth watermark -----------------------
n, s, b = 6, 2, 4
A = jnp.asarray(rng.standard_normal((512, 768)).astype(np.float32))
plan = compile_plan(A, scheme="proposed", n=n, s=s)
xs = [jnp.asarray(rng.standard_normal((b, 512)), jnp.float32)
      for _ in range(48)]

router = Router(batch_wait_s=0.002)
router.register("head", plan, replicas=1, n_workers=n,
                transport="memory", min_cols=1, max_cols=32)
router.call("head", xs[0])                           # warm the first replica

scaler = Autoscaler(router, endpoint="head", n_workers=n,
                    policy=QueueDepthPolicy(high=2 * b, low=1),
                    min_members=1, max_members=3,
                    interval_s=0.05, cooldown_s=0.1).start()
print(f"autoscaler up: pool={scaler.pool.kind} size={scaler.pool.size()} "
      f"bounds=[1, 3] policy=queue-depth(high={2 * b}, low=1)")

router.pause()                                       # the ramp: queue, don't serve
futs = [router.submit("head", x) for x in xs]
time.sleep(0.3)                                      # a few controller ticks
ramped = scaler.pool.size()
router.resume()

# --- act 2: the scaled-out pool serves the burst, bitwise-checked -----------
peak, bad = ramped, 0
for i, f in enumerate(futs):
    got = np.asarray(f.result(60))
    peak = max(peak, scaler.pool.size())
    # decode picks whichever k-subset finished first, so compare
    # against the exact product, not one particular pattern's decode
    exact = np.asarray(xs[i] @ A)
    if np.linalg.norm(got - exact) > 1e-3 * np.linalg.norm(exact):
        bad += 1
print(f"\nburst: {len(futs)} calls ({len(futs) * b} cols) served, "
      f"replicas 1 -> {peak} under load, {bad} results off the exact "
      f"product")

# --- act 3: idle drains the pool back to the floor, one step per tick -------
t0 = time.monotonic()
while scaler.pool.size() > 1 and time.monotonic() - t0 < 30:
    # spaced probes: a probe permanently in flight would hold the
    # queue-depth shrink (it requires an idle endpoint)
    router.submit("head", xs[0]).result(60)
    time.sleep(0.1)
acts = [d for d in scaler.decision_log() if d["action"] != "hold"]
print(f"idle: pool back to {scaler.pool.size()} after "
      f"{time.monotonic() - t0:.1f}s")
print("decisions:", " ".join(
    f"{d['action']}({d['reason']},{d['size']}->{d['target']})"
    for d in acts))
scaler.close()
router.close()

# --- act 4: straggler storm -> measured rates cut the grown encoding --------
A2 = jnp.asarray(rng.standard_normal((256, 144)).astype(np.float32))
plan2 = compile_plan(A2, scheme="proposed", n=8, s=2, backend="packed")
x2 = jnp.asarray(rng.standard_normal((256,)), jnp.float32)
slow = 0
tr = Tracer(capacity=4096)
storm = adversarial_faults([slow], slowdown=60.0, time_scale=2e-3)
with CodedFleet(4, grow_encodings=True, faults=storm, tracer=tr) as fleet:
    h = fleet.attach(plan2)
    for _ in range(16):                              # storm under observation
        h.matvec(x2)
        time.sleep(0.01)
    rates = fleet.observed_rates()
    print(f"\nstorm: measured rates "
          f"{ {w: round(r, 1) for w, r in sorted(rates.items())} } "
          f"(worker {slow} seeded slow)")
    scaler2 = Autoscaler(fleet, policy=SchedulePolicy([(0, 4), (0.2, 6)]),
                         min_members=2, max_members=8,
                         interval_s=0.05, cooldown_s=0).start()
    before = (h.plan.n, h.plan.k, h.plan.s)
    pid0 = h.plan_id
    t0 = time.monotonic()
    while (len(fleet.live_workers()) < 6 or h.plan_id == pid0) \
            and time.monotonic() - t0 < 30:
        time.sleep(0.05)
    scaler2.close()
    owned = {w: 0 for w in fleet.live_workers()}
    for o in h._ps.owner.values():
        owned[o] += 1
    y = np.asarray(h.matvec(x2))
    exact = np.asarray(x2 @ A2)
    err = np.linalg.norm(y - exact) / np.linalg.norm(exact)
    print(f"grown: (n,k,s) {before} -> "
          f"{(h.plan.n, h.plan.k, h.plan.s)} scheme={h.plan.scheme.name}")
    print(f"rows owned per worker: {dict(sorted(owned.items()))} "
          f"(slow worker {slow} gets the fewest)")
    print(f"decode parity on the grown code: rel err {err:.2e}")

print("\nloop closed: load ramped capacity up, idle walked it back, and "
      "the storm's measured rates shaped the grown encoding.")
