"""The paper's deployment scenario: an edge server offloads a sparse
matrix product to a heterogeneous fleet with partial stragglers.

Reproduces the Example 4 system (n_bar = 8 physical devices with
capacities 3,2,2,1,1,1,1,1 -> n = 12 virtual workers, k_A = 9, s = 3),
runs a Monte-Carlo straggler simulation with per-worker compute cost
proportional to the encoded nnz, and compares job completion across
schemes -- including the partial-straggler case where strong devices
finish only some of their virtual tasks.

    PYTHONPATH=src python examples/edge_offload.py
"""

import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.api import compile_plan, make_scheme
from repro.core import ShiftedExponential, make_hetero_system, simulate_job

rng = np.random.default_rng(0)

# --- Example 4's heterogeneous system --------------------------------------
capacities = [3, 2, 2, 1, 1, 1, 1, 1]
system = make_hetero_system(capacities)
k_A = sum(system.capacities[:5])      # 9
s = system.n - k_A                    # 3
print(f"physical devices: {system.n_bar}, capacities {system.capacities}")
print(f"virtual workers: n={system.n}, k_A={k_A}, s={s}")

# --- sparse job, plan compiled once over the virtualised system -------------
t, r = 1800, 1350
A = rng.standard_normal((t, r)) * (rng.random((t, r)) < 0.02)
x = rng.standard_normal(t)
op = compile_plan(jnp.asarray(A, jnp.float32), scheme="proposed-hetero",
                  capacities=capacities, k_A=k_A, seed=0, backend="auto")
scheme = op.scheme
print(f"weight omega_A = {scheme.omega_A} "
      f"(cyclic[31] would use {min(s + 1, k_A)}); "
      f"backend={op.backend}\n")

# --- full straggler: any one strong device (3 virtual workers) dies ---------
done = np.ones(system.n, bool)
done[list(system.virtual_of[0])] = False     # the capacity-3 device dies
y = op.matvec(jnp.asarray(x, jnp.float32), jnp.asarray(done))
err = np.max(np.abs(np.asarray(y) - A.T @ x)) / np.max(np.abs(A.T @ x))
print(f"strong device (3 virtual workers) fails -> rel err {err:.2e}")

# --- partial stragglers: strong devices finish SOME virtual tasks -----------
done = np.ones(system.n, bool)
done[system.virtual_of[0][2:]] = False       # W0 finishes 2/3
done[system.virtual_of[1][1:]] = False       # W1 finishes 1/2
done[system.virtual_of[2][1:]] = False       # W2 finishes 1/2
assert done.sum() >= k_A
y = op.matvec(jnp.asarray(x, jnp.float32), jnp.asarray(done))
err = np.max(np.abs(np.asarray(y) - A.T @ x)) / np.max(np.abs(A.T @ x))
print(f"partial stragglers (2/3, 1/2, 1/2 done) -> rel err {err:.2e}\n")

# --- Monte-Carlo job-completion comparison ----------------------------------
print("job completion time (p50 over 500 rounds, shifted-exp model):")
nnz_blocks = [(np.abs(A[:, c * (r // k_A):(c + 1) * (r // k_A)]) > 0).sum()
              for c in range(k_A)]
base = float(np.mean(nnz_blocks))
for name in ("poly", "rkrp", "cyclic31", "proposed"):
    sch = make_scheme(name, n=system.n, k_A=k_A)
    work = np.array([sum(nnz_blocks[q] for q in sch.supports[i])
                     for i in range(system.n)]) / base
    stats = simulate_job(work, k=k_A, model=ShiftedExponential(),
                         rng=np.random.default_rng(1), n_rounds=500)
    print(f"  {name:10s} p50={stats['p50']:.2f}  p99={stats['p99']:.2f}  "
          f"(mean worker load {work.mean():.2f}x uncoded)")
