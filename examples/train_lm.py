"""End-to-end training driver: train a small LM for a few hundred steps
on the synthetic pipeline, with checkpointing and auto-resume.

Default trains a ~13M-parameter qwen3-family model for 200 steps on CPU
(a few minutes); ``--params 100m --steps 300`` scales to the ~100M-class
run on real hardware.  Loss decreases monotonically thanks to the copy
motifs planted by the pipeline.

After training, demonstrates coded gradient aggregation through the
plan API: the final step's gradients are split over k data shards and
summed exactly from any n - s workers (an aggregation-only
``repro.api.CodedPlan`` with the LRU-cached per-pattern decode).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs.base import AttnConfig, ModelConfig
from repro.data import DataConfig, make_pipeline
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train import TrainConfig, Trainer

SIZES = {
    "13m": dict(n_layers=4, d_model=256, d_ff=768, n_heads=4, kv=2, hd=64),
    "30m": dict(n_layers=6, d_model=384, d_ff=1152, n_heads=6, kv=2, hd=64),
    "100m": dict(n_layers=12, d_model=640, d_ff=1920, n_heads=10, kv=2, hd=64),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--params", choices=SIZES, default="13m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    sz = SIZES[args.params]
    cfg = ModelConfig(
        name=f"lm-{args.params}", family="dense", n_layers=sz["n_layers"],
        d_model=sz["d_model"], d_ff=sz["d_ff"], vocab=args.vocab,
        attn=AttnConfig(n_heads=sz["n_heads"], n_kv_heads=sz["kv"],
                        head_dim=sz["hd"], qk_norm=True),
        tie_embeddings=True, max_seq=args.seq, remat="none")
    model = build_model(cfg, dtype=jnp.float32)
    print(f"model: {cfg.param_count() / 1e6:.1f}M params, "
          f"{cfg.n_layers}L d={cfg.d_model}")

    dcfg = DataConfig(vocab=args.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    tcfg = TrainConfig(steps=args.steps, ckpt_every=max(50, args.steps // 4),
                       ckpt_dir=args.ckpt_dir)
    trainer = Trainer(
        model, AdamWConfig(lr=args.lr, warmup_steps=args.steps // 10,
                           total_steps=args.steps), tcfg)
    _, _, hist = trainer.fit(lambda s0: make_pipeline(dcfg, s0),
                             rng=jax.random.key(0))
    for h in hist:
        if h["step"] % 20 == 0 or h["step"] == args.steps - 1:
            print(f"step {h['step']:4d}  loss {h['loss']:.4f}  "
                  f"{h['dt'] * 1e3:6.0f} ms/step")
    if hist:
        first = sum(h["loss"] for h in hist[:5]) / 5
        last = sum(h["loss"] for h in hist[-5:]) / 5
        print(f"\nloss: {first:.3f} -> {last:.3f} "
              f"({'improved' if last < first else 'NOT improved'})")

    # --- coded gradient aggregation through the plan API -----------------
    import numpy as np

    from repro.parallel import CodedAggregator

    n_workers, stragglers = 6, 2
    agg = CodedAggregator.build(n_workers, stragglers, seed=0)
    k = n_workers - stragglers
    rng = np.random.default_rng(0)
    # stand-in per-shard gradients (one pytree per data shard)
    shard_grads = [
        jax.tree.map(lambda p: jnp.asarray(
            rng.standard_normal(p.shape), jnp.float32),
            model.init(jax.random.key(1)))
        for _ in range(k)]
    payloads = [agg.worker_payload(i, shard_grads) for i in range(n_workers)]
    expect = jax.tree.map(lambda *xs: sum(xs), *shard_grads)
    done = np.ones(n_workers, bool)
    done[rng.choice(n_workers, stragglers, replace=False)] = False
    out = agg.aggregate(payloads, jnp.asarray(done))
    err = max(float(jnp.abs(a - b).max())
              for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(expect)))
    print(f"coded grad aggregation: {stragglers}/{n_workers} workers lost, "
          f"sum exact to {err:.2e} "
          f"(weight {max(len(t) for t in agg.shard_assignment)} per worker)")


if __name__ == "__main__":
    main()
