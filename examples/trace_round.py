"""End-to-end round tracing + straggler attribution (repro.obs).

The paper's whole premise is that stragglers dominate edge wall-clock
-- but a production fleet needs to know *which* worker is slow and in
*which phase* (wire? queue? compute?) before it can act.  This example
threads a ``Tracer`` through a live fleet:

  * every round becomes a span tree -- coordinator queue, per-worker
    wire-out / worker-queue / compute / wire-back, decode -- on one
    monotonic timeline (worker clocks are re-anchored via the
    transport's hello clock handshake, tightened per traced result);
  * one worker is deliberately made 40x slower; ``attribute()`` names
    it from the trace alone (rounds decoded *without* it, its measured
    compute rate) and its rate feeds ``worker_capacities(rates=...)``
    -- the capacity vector heterogeneity-aware schemes virtualize
    devices with;
  * the merged timeline ships as a Chrome trace: open trace_round.json
    at https://ui.perfetto.dev and look at the per-worker tracks.

Tracing costs one pointer check per instrumented site when disabled
(tracer=None, the default); flip it on globally with REPRO_TRACE=1.

    PYTHONPATH=src python examples/trace_round.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.api import CodedFleet, compile_plan
from repro.cluster.faults import adversarial_faults
from repro.obs import Tracer, attribute, write_chrome_trace

rng = np.random.default_rng(0)
n, s, b = 8, 2, 4
SLOW = 3

mask = np.kron(rng.random((32, 24)) >= 0.95, np.ones((8, 8)))
A = jnp.asarray((rng.standard_normal((256, 192)) * mask)
                .astype(np.float32))
plan = compile_plan(A, scheme="proposed", n=n, s=s, backend="packed")
xs = [jnp.asarray(rng.standard_normal((b, 256)), jnp.float32)
      for _ in range(12)]

tracer = Tracer()
with CodedFleet(n, transport="memory", tracer=tracer,
                faults=adversarial_faults([SLOW], slowdown=40.0,
                                          time_scale=2e-3)) as fleet:
    for i, x in enumerate(xs):
        h = fleet.attach(plan) if i == 0 else h
        h.matvec(x)
        time.sleep(0.01)        # pace: let healthy workers drain

    report = attribute(tracer.events())
    print(f"traced {len(report.rounds)} rounds; "
          f"worker {SLOW} seeded 40x slow\n")
    print(report.table())

    print("\nwhere does round latency go? (critical-chain segment "
          "totals)")
    totals = report.phase_totals()
    for phase, total in sorted(totals.items(), key=lambda kv: -kv[1]):
        print(f"  {phase:<13} {total * 1e3:8.2f} ms total")

    suspect = report.suspects()[0]
    print(f"\nattribution's top suspect: worker {suspect} "
          f"({'correct' if suspect == SLOW else 'WRONG'})")
    print(f"wasted work (computed but not decoded): "
          f"{report.wasted_work():.1f} units")

    # traced compute rates -> capacity levels for hetero-aware schemes
    rates = report.compute_rates()
    ws = sorted(report.workers)
    caps = fleet.worker_capacities(workers=ws, rates=rates)
    print("\ntraced compute rate -> capacity level:")
    for w, cap in zip(ws, caps):
        rate = rates.get(w)
        shown = f"{rate:7.1f} work/s" if rate else "   (no sample)"
        print(f"  worker {w}: {shown} -> level {cap}"
              + ("   <- seeded straggler" if w == SLOW else ""))

    n_events = write_chrome_trace("trace_round.json", tracer,
                                  fleet=fleet)
print(f"\nwrote {n_events} events to trace_round.json "
      f"(open at https://ui.perfetto.dev)")
