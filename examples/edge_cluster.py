"""Compile -> ship -> dispatch -> decode on the real cluster runtime.

The paper's deployment, end to end: an edge server compiles a
sparsity-preserving coded plan for a sparse operator, serializes it into
per-worker shards (``repro.cluster.wire``), ships them to workers over a
pluggable transport (in-process ``memory`` here; flip ``TRANSPORT`` or
set ``REPRO_CLUSTER_TRANSPORT=tcp`` for real localhost sockets), and
then serves matvecs by racing the workers -- decoding as soon as any
fastest-k task set reports, while injected shifted-exponential latency
makes the run reproducibly straggly.  Task payloads are
support-restricted (only the x-blocks a worker's nonzero tiles read
travel), so the wire carries omega/k-proportional bytes -- printed per
round.  Later passes show adversarial slowdown (partial-straggler
credit), worker fail-stop with requeue, and a *silent* worker caught
purely by heartbeat timeout (suspected -> shard re-shipped -> requeue).

    PYTHONPATH=src python examples/edge_cluster.py
"""

import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.api import compile_plan
from repro.cluster import (
    FailStop,
    Hang,
    StragglerFaults,
    adversarial_faults,
    dumps_plan,
    shard_plan,
)

TRANSPORT = "memory"              # or "pipe" / "tcp" -- same results

rng = np.random.default_rng(0)

# --- a 98%-block-sparse operator, plan compiled once ------------------------
n, k = 12, 9                      # s = 3 stragglers tolerated
t, r = 1024, 720
mask = rng.random((t // 8, r // 8)) >= 0.98
A = jnp.asarray((rng.standard_normal((t, r)) *
                 np.kron(mask, np.ones((8, 8)))).astype(np.float32))
x = jnp.asarray(rng.standard_normal((4, t)), jnp.float32)
ref = np.asarray(x @ A)

plan = compile_plan(A, scheme="proposed", n=n, s=n - k, backend="packed")
blob = dumps_plan(plan)
shards = shard_plan(plan, n_workers=4)
print(f"compiled: scheme={plan.scheme.name} n={n} k={k} "
      f"omega={plan.scheme.omega_A} backend={plan.backend}")
print(f"wire: plan={len(blob) / 1e3:.1f} kB, "
      f"shards={[len(s.encode()) // 1024 for s in shards]} kiB "
      f"over 4 hosts\n")

# --- race the workers under shifted-exponential stragglers ------------------
with plan.to_cluster(transport=TRANSPORT,
                     faults=StragglerFaults(time_scale=0.05, seed=1)) as cl:
    for i in range(3):
        y = cl.matvec(x)                      # decode at fastest-k
        rep = cl.last_report
        err = np.abs(np.asarray(y) - ref).max()
        print(f"round {i}: wall={rep.wall_s * 1e3:6.1f} ms  "
              f"decode={rep.decode_s * 1e6:5.0f} us  "
              f"decoded_from={rep.n_done}/{rep.n_tasks}  "
              f"task_kB={rep.bytes_tasks / 1e3:5.1f} "
              f"(dense would ship {rep.bytes_tasks_dense / 1e3:.1f})  "
              f"err={err:.1e}")
    tot = cl.wire_totals()
    print(f"totals[{tot['transport']}]: shards={tot['bytes_shards'] / 1e3:.1f} kB "
          f"once, tasks={tot['bytes_tasks_total'] / 1e3:.1f} kB over 3 rounds")

# --- partial stragglers: 4 hosts, host 0 is adversarially slow --------------
print("\n4 physical hosts x 3 virtual workers, host 0 is 25x slow:")
with plan.to_cluster(4, transport=TRANSPORT,
                     faults=adversarial_faults([0], slowdown=25.0,
                                               time_scale=0.05)) as cl:
    y = cl.matvec(x)
    rep = cl.last_report
    err = np.abs(np.asarray(y) - ref).max()
    print(f"  decoded from {rep.n_done} rows, partial hosts "
          f"{list(rep.partial_workers)} (finished SOME of their rows), "
          f"err={err:.1e}")

# --- fail-stop + requeue: two workers die; their shards are re-homed --------
print("\nfail-stop: workers 2 and 5 die on first task (k needs requeue):")
with plan.to_cluster(transport=TRANSPORT, faults=FailStop({2: 0, 5: 0})) as cl:
    y = cl.matvec(x)
    rep = cl.last_report
    err = np.abs(np.asarray(y) - ref).max()
    print(f"  deaths={rep.deaths} requeues={rep.requeues} "
          f"decoded_from={rep.n_done}  err={err:.1e}")
    y = cl.matvec(x)                          # cluster keeps serving
    print(f"  next round on {n - rep.deaths} survivors: "
          f"err={np.abs(np.asarray(y) - ref).max():.1e}")

# --- silent workers: liveness is measured, not injected ---------------------
print("\nsilent hang: workers 1, 4, 7, 10 go mute mid-round (no death notice,")
print("connection stays open; 8 < k live) -- only heartbeat timeout helps:")
with plan.to_cluster(transport=TRANSPORT,
                     faults=Hang({1: 0, 4: 0, 7: 0, 10: 0}),
                     heartbeat_s=0.05, suspect_after=0.5) as cl:
    y = cl.matvec(x)
    rep = cl.last_report
    err = np.abs(np.asarray(y) - ref).max()
    print(f"  suspected={rep.suspected} requeues={rep.requeues} "
          f"decoded_from={rep.n_done}  err={err:.1e}")
