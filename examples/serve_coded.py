"""Batched serving with a straggler-resilient coded LM head.

Serves a wave of requests through the engine, then demonstrates the
paper's feature end-to-end: the final logits matmul runs through a
precompiled CodedPlan (Alg. 1 via the scheme registry, n=6 workers,
s=2, backend="auto") under fresh random straggler masks every step --
outputs are bit-stable regardless of WHICH two workers die, and the
per-worker compute is omega/k = 2/4 of the logical matmul instead of
the k/k a dense MDS code would need.

    PYTHONPATH=src python examples/serve_coded.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import CodedConfig
from repro.models import build_model
from repro.serve import Request, ServeEngine

cfg = get_smoke_config("qwen3-14b")
model = build_model(cfg, dtype=jnp.float32)
params = model.init(jax.random.key(0))

engine = ServeEngine(model, params, cfg, batch_size=4, max_len=64,
                     coded=CodedConfig(enabled=True, n_workers=6,
                                       stragglers=2, scheme="proposed",
                                       backend="auto"))
print(f"coded LM head plan: {engine.coded.describe()}")

# --- batched generation ----------------------------------------------------
reqs = [Request(prompt=[1, 17, 42], max_new=8),
        Request(prompt=[1, 5], max_new=8),
        Request(prompt=[1, 99, 3, 7], max_new=8),
        Request(prompt=[1], max_new=8)]
out = engine.run(reqs)
for i, r in enumerate(out):
    print(f"req {i}: prompt {r.prompt} -> {r.output}")

# --- coded-head resilience check -------------------------------------------
rng = np.random.default_rng(0)
hidden = jnp.asarray(rng.standard_normal((4, cfg.d_model)), jnp.float32)
head = params["embed"].T if cfg.tie_embeddings else params["head"]
ref = np.asarray(hidden @ head)

print("\ncoded LM head under 5 random straggler patterns:")
for trial in range(5):
    logits = engine.coded_logits(hidden)   # fresh straggler mask inside
    err = np.max(np.abs(np.asarray(logits) - ref)) / np.max(np.abs(ref))
    print(f"  trial {trial}: max rel err vs uncoded head = {err:.2e}")
    assert err < 1e-2
stats = engine.coded.describe().get("decode_cache",
                                    "n/a (reference backend)")
print(f"decode cache after 5 trials: {stats}")
print("OK: any 2 of 6 workers can die; logits unchanged")
